"""Serving example: batched autoregressive decode with merged LoRA
weights — the deployment end of the federated fine-tune (train with
bind, serve with merge), across architecture families.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.factory import build_model
from repro.peft import lora

for arch in ("qwen3-1.7b", "rwkv6-1.6b", "recurrentgemma-2b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    # pretend a federated run produced this adapter; merge for serving
    lt = lora.init_lora(jax.random.fold_in(key, 1), params,
                        lora.default_targets(cfg), rank=4)
    lt = jax.tree.map(lambda x: x + 0.01, lt)
    served = lora.merge(params, lt, alpha=32.0, rank=4)

    B, P, G = 4, 8, 24
    prompt = jax.random.randint(jax.random.fold_in(key, 2), (B, P), 1,
                                cfg.vocab_size, jnp.int32)
    cache = model.init_cache(served, B, P + G, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    t0, tok = time.time(), prompt[:, 0]
    for t in range(P + G):
        tok_in = prompt[:, t] if t < P else tok
        logits, cache = step(served, cache, tok_in, jnp.asarray(t))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch:18s} ({cfg.family:6s}): {B}x{G} tokens in "
          f"{time.time()-t0:.2f}s (greedy, merged-LoRA serving)")
