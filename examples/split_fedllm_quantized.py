"""Split-FedLLM scenario: activation-based updates with the paper's
SSIV.C directions — int8 activation/gradient transfer and resource-aware
dynamic split-point selection.

    PYTHONPATH=src python examples/split_fedllm_quantized.py
"""
from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core.split import choose_split_point
from repro.core.rounds import run_federated
from repro.data import banking77, partition


def main():
    cfg = gpt2_tiny()
    public, train, test = banking77.paper_splits(cfg.vocab_size,
                                                 pad_len=24, scale=0.06)
    clients = partition.iid_partition(train, 3)

    # SSIV.C.1: pick the split point from a client FLOPs budget
    n_tok_round = len(clients[0]["tokens"]) * 24
    for budget in (1e10, 1e13):
        L = choose_split_point(cfg, budget, n_tok_round)
        print(f"client budget {budget:.0e} FLOPs/round -> split at "
              f"layer {L}/{cfg.n_layers}")

    # bf16 vs int8 activation transfer (SSIV.C.2)
    for bits, tag in ((0, "fp32 wire"), (8, "int8 wire")):
        fed = FedConfig(framework="split", n_clients=3, rounds=3,
                        lora_rank=4, split_layer=2,
                        activation_quant_bits=bits, seed=0)
        res = run_federated(cfg, fed, public, clients, test, batch_size=16)
        acts = res.ledger.by_name()["activations"]
        print(f"{tag}: acc={res.final_accuracy:.3f} "
              f"activation_bytes={acts:.2e} "
              f"comm/client/round="
              f"{res.ledger.mean_client_bytes_per_round():.2e}B")
    print("\nExpected: int8 cuts the dominant activation wire ~4x with "
          "minimal accuracy change (paper SSIV.C.2).")


if __name__ == "__main__":
    main()
