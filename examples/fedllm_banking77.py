"""End-to-end driver (charter b): the paper's SSV case study — federated
LoRA fine-tuning of a GPT-2-family model on Banking77-style intent
classification, 3 clients, with the LoRA-rank ablation of Fig. 3(a),
a few hundred local steps total.

    PYTHONPATH=src python examples/fedllm_banking77.py [--rounds 8]
"""
import argparse

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core.rounds import run_federated
from repro.data import banking77, partition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.08,
                    help="fraction of the paper's 10k-sample setup")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--non-iid", action="store_true",
                    help="dirichlet(0.5) label-skew partition")
    args = ap.parse_args()

    cfg = gpt2_tiny()
    public, train, test = banking77.paper_splits(
        cfg.vocab_size, pad_len=32, seed=args.seed, scale=args.scale)
    if args.non_iid:
        clients = partition.dirichlet_partition(train, 3, alpha=0.5,
                                                seed=args.seed)
    else:
        clients = partition.iid_partition(train, 3, seed=args.seed)
    print(f"clients: {[len(c['tokens']) for c in clients]} samples, "
          f"test: {len(test['tokens'])}")

    for rank in (2, 4, 8):
        fed = FedConfig(framework="fedllm", n_clients=3,
                        rounds=args.rounds, lora_rank=rank, lr=1e-3,
                        lora_dropout=0.1, seed=args.seed)
        res = run_federated(cfg, fed, public, clients, test,
                            batch_size=16, verbose=False)
        accs = [h.accuracy for h in res.history]
        print(f"rank={rank}: acc {accs[0]:.3f} -> {accs[-1]:.3f}  "
              f"comm/client/round="
              f"{res.ledger.mean_client_bytes_per_round():.2e}B")
    print("\nExpected (paper Fig. 3a/4): higher rank -> higher accuracy "
          "and proportionally higher comm.")


if __name__ == "__main__":
    main()
