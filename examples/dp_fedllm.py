"""Differentially-private federated LoRA fine-tuning: the epsilon-vs-
accuracy trade-off (paper SSVI research direction, PrivacyConfig).

Sweeps the Gaussian noise multiplier over the paper's SSV case study
(Banking77-style intent classification, 3 clients) with per-example
DP-SGD clipping and simulated secure aggregation on, and prints the
(eps, delta) the RDP accountant reports next to final accuracy and the
wire overhead the privacy machinery costs.

    PYTHONPATH=src python examples/dp_fedllm.py [--rounds 8]
"""
import argparse
import dataclasses

from repro.configs.base import FedConfig, PrivacyConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core.rounds import run_federated
from repro.data import banking77, partition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.08,
                    help="fraction of the paper's 10k-sample setup")
    ap.add_argument("--clip", type=float, default=1.0,
                    help="per-example L2 clip C")
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="sequential",
                    choices=["sequential", "spmd"])
    args = ap.parse_args()

    cfg = gpt2_tiny()
    public, train, test = banking77.paper_splits(
        cfg.vocab_size, pad_len=32, seed=args.seed, scale=args.scale)
    clients = partition.iid_partition(train, 3, seed=args.seed)
    print(f"clients: {[len(c['tokens']) for c in clients]} samples, "
          f"test: {len(test['tokens'])}")

    fed0 = FedConfig(framework="fedllm", backend=args.backend, n_clients=3,
                     rounds=args.rounds, lora_rank=4, lr=1e-3,
                     lora_dropout=0.0, seed=args.seed)
    print(f"{'sigma':>6} {'epsilon':>9} {'accuracy':>9} "
          f"{'privacy-overhead B/client/round':>32}")
    for sigma in (0.0, 0.3, 0.6, 1.2, 2.4):
        priv = PrivacyConfig(dp_clip=args.clip if sigma else 0.0,
                             dp_noise_multiplier=sigma,
                             dp_delta=args.delta, secure_agg=True)
        fed = dataclasses.replace(fed0, privacy=priv)
        res = run_federated(cfg, fed, public, clients, test,
                            batch_size=16, eval_batch=64)
        eps = res.history[-1].epsilon
        if sigma:
            # pin the engine-reported epsilon to the subsampled-Gaussian
            # accountant at the run's actual sampling rate q = B/|data|
            from repro.privacy.accountant import GaussianAccountant
            q = max(min(1.0, 16 / len(c["tokens"])) for c in clients)
            want = GaussianAccountant(sigma, args.delta,
                                      sample_rate=q).epsilon(args.rounds)
            assert eps == want, (eps, want)
        overhead = res.ledger.privacy_overhead_bytes() \
            / (fed.rounds * fed.n_clients)
        print(f"{sigma:6.1f} {eps if eps else float('inf'):9.2f} "
              f"{res.final_accuracy:9.3f} {overhead:32.1f}")
    print("\nExpected: accuracy degrades as sigma grows (epsilon "
          "shrinks, amplified by the q = batch/|data| subsampling rate "
          "the engines report); the secure-agg/DP wire overhead is "
          "constant and tiny next to the adapter payload (Fig. 4 "
          "column).")


if __name__ == "__main__":
    main()
