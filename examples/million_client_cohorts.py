"""Million-client rounds: cohort streaming over a lazy ClientPopulation
(ROADMAP scale story; paper SSVI cross-device directions).

Two parts:

1. A *laziness demo*: build a 100k-virtual-client DirichletPopulation
   over a small base dataset and materialize exactly one cohort —
   showing the fleet costs O(base data) resident memory and cohort
   materialization is O(cohort), bit-stable in any order.
2. A *training run* at tractable scale: the same population API driven
   through ``FedConfig(backend="cohort")``, streaming each round
   ``cohort_size`` clients at a time, optionally with hierarchical
   (client->edge->server) aggregation accounting via ``--n-edges``.

    PYTHONPATH=src python examples/million_client_cohorts.py
    PYTHONPATH=src python examples/million_client_cohorts.py \
        --n-virtual 2000 --cohort-size 128 --n-edges 4
"""
import argparse

import numpy as np

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core import metrics as M
from repro.core.rounds import run_federated
from repro.data import banking77
from repro.data.population import DirichletPopulation


def laziness_demo(base, n_virtual: int, cohort_size: int, alpha: float):
    pop = DirichletPopulation(base, n_virtual, alpha=alpha, seed=7,
                              shard_size=16)
    resident = sum(a.nbytes for a in pop.__dict__.values()
                   if isinstance(a, np.ndarray))
    resident += sum(a.nbytes for a in pop.base.values())
    print(f"population: {len(pop):,} virtual clients over "
          f"{len(base['tokens'])} base samples "
          f"({resident / 2**20:.2f} MiB resident, "
          f"{pop.n_cohorts(cohort_size):,} cohorts of {cohort_size})")
    cohort = pop.cohort(0, pop.n_cohorts(cohort_size) // 2, cohort_size)
    shard_bytes = sum(a.nbytes for d in cohort.data for a in d.values())
    print(f"materialized cohort {cohort.index}: clients "
          f"{cohort.clients[0]:,}..{cohort.clients[-1]:,} "
          f"({shard_bytes / 2**20:.2f} MiB — the streaming peak)")
    # bit-stable: revisiting a client reproduces its shard exactly
    again = pop.client(cohort.clients[3])
    assert np.array_equal(cohort.data[3]["tokens"], again["tokens"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-virtual", type=int, default=512,
                    help="virtual fleet size for the training run")
    ap.add_argument("--lazy-demo-virtual", type=int, default=100_000,
                    help="fleet size for the no-training laziness demo")
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--n-edges", type=int, default=0,
                    help="edge aggregators for hierarchical accounting "
                         "(0 = flat)")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="Dirichlet non-IID concentration")
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--framework", default="fedllm",
                    choices=["fedllm", "kd", "split"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = gpt2_tiny()
    public, train, test = banking77.paper_splits(cfg.vocab_size,
                                                 pad_len=24, scale=0.04,
                                                 seed=args.seed)

    print("== laziness demo (no training) ==")
    laziness_demo(train, args.lazy_demo_virtual, args.cohort_size,
                  args.alpha)

    print(f"\n== cohort-streaming round(s): {args.n_virtual} virtual "
          f"clients, {args.cohort_size}/cohort ==")
    pop = DirichletPopulation(train, args.n_virtual, alpha=args.alpha,
                              seed=args.seed, shard_size=16)
    fed = FedConfig(framework=args.framework, backend="cohort",
                    n_clients=args.n_virtual, rounds=args.rounds,
                    cohort_size=args.cohort_size,
                    n_virtual_clients=args.n_virtual,
                    n_edges=args.n_edges, lora_rank=4, lora_dropout=0.0,
                    split_layer=2, kd_epochs=1, seed=args.seed)
    result = run_federated(cfg, fed, public, pop, test, batch_size=8,
                           eval_batch=32, verbose=True)
    print(f"final accuracy: {result.final_accuracy:.4f}")
    by_hop = result.ledger.by_hop()
    for hop in (M.CLIENT_SERVER, M.CLIENT_EDGE, M.EDGE_SERVER):
        if hop in by_hop:
            print(f"  {hop:>13}: {by_hop[hop] / 2**20:.2f} MiB")
    print(f"  per-client/round: "
          f"{result.history[-1].comm_bytes_per_client / 2**10:.1f} KiB")


if __name__ == "__main__":
    main()
