"""Quickstart: the paper's three federated fine-tuning frameworks in ~40
lines against one shared substrate.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core.rounds import run_federated
from repro.data import banking77, partition

# 1. The case-study setup (paper SSV, reduced): GPT-2-family model,
#    Banking77-style intent classification, 3 clients, public set for KD.
cfg = gpt2_tiny()
public, train, test = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                             scale=0.04)
clients = partition.iid_partition(train, n_clients=3)

# 2. Run one round of each framework; everything (accuracy, per-client
#    communication bytes, client-side FLOPs) is measured by the engine.
for framework in ("fedllm", "kd", "split"):
    fed = FedConfig(framework=framework, n_clients=3, rounds=2,
                    lora_rank=4, split_layer=2, kd_epochs=1, seed=0)
    res = run_federated(cfg, fed, public, clients, test, batch_size=16)
    last = res.history[-1]
    print(f"{framework:7s} acc={last.accuracy:.3f} "
          f"comm/client/round={last.comm_bytes_per_client:.2e}B "
          f"client_flops={last.client_flops:.2e}")

print("\nPaper Table I orderings should be visible above: "
      "split=highest comm, kd=highest compute.")
