"""KD-FedLLM scenario: logit-based knowledge sharing, then the paper's
SSIV.B research directions as working features — top-k logit compression
and public-dataset alignment under non-IID clients.

    PYTHONPATH=src python examples/kd_fedllm_compressed.py
"""
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core import kd
from repro.core.rounds import run_federated
from repro.data import banking77, partition


def main():
    cfg = gpt2_tiny()
    public, train, test = banking77.paper_splits(cfg.vocab_size,
                                                 pad_len=24, scale=0.06)
    clients = partition.dirichlet_partition(train, 3, alpha=0.5, seed=0)

    # baseline KD (dense logits)
    fed = FedConfig(framework="kd", n_clients=3, rounds=3, lora_rank=4,
                    kd_epochs=1, seed=0)
    base = run_federated(cfg, fed, public, clients, test, batch_size=16)
    base_bytes = base.ledger.by_name()["logits"]
    print(f"dense-logit KD:  acc={base.final_accuracy:.3f} "
          f"logit_bytes={base_bytes:.2e}")

    # SSIV.B.2: top-k logit compression
    fed_tk = FedConfig(framework="kd", n_clients=3, rounds=3, lora_rank=4,
                       kd_epochs=1, logit_topk=8, seed=0)
    topk = run_federated(cfg, fed_tk, public, clients, test, batch_size=16)
    tk_bytes = topk.ledger.by_name()["logits"]
    print(f"top-8 KD:        acc={topk.final_accuracy:.3f} "
          f"logit_bytes={tk_bytes:.2e} "
          f"({base_bytes/tk_bytes:.1f}x smaller wire)")

    # SSIV.B.1: public-dataset alignment from client label histograms
    hists = [partition.label_histogram(c) for c in clients]
    aligned_pub = kd.align_public_dataset(public, hists,
                                          len(public["tokens"]), seed=1)
    al = run_federated(cfg, fed, aligned_pub, clients, test, batch_size=16)
    print(f"aligned-PD KD:   acc={al.final_accuracy:.3f} "
          f"(public set resampled toward client label mix)")


if __name__ == "__main__":
    main()
