"""Render results/*.json into the markdown tables EXPERIMENTS.md embeds."""
import json
import sys


def dryrun_table(path="results/dryrun_all.json"):
    rows = json.load(open(path))
    out = ["| arch | shape | mesh | step | compile_s | args GiB/dev | "
           "temp GiB/dev | collective GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['step']} | SKIP(policy) | — | — | — |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
                f"| {r['compile_s']} | {r['arg_gib_per_dev']:.2f} "
                f"| {r['temp_gib_per_dev']:.2f} "
                f"| {r.get('collective_total', 0)/1e9:.2f} |")
    return "\n".join(out)


def roofline_table(path="results/roofline_table.json"):
    rows = json.load(open(path))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPs | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("error"):
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['useful_ratio']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("both", "dryrun"):
        print(dryrun_table())
        print()
    if which in ("both", "roofline"):
        print(roofline_table())
