"""Derive the full single-pod roofline table (charter g): per (arch x
shape) lower the stem + one-group variants unrolled, scale by layer
count, and write results/roofline_table.json.

    PYTHONPATH=src python scripts/run_roofline.py [--arch A --shape S]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

from repro.configs.registry import ARCHS, get_config       # noqa: E402
from repro.configs.shapes import SHAPES, shape_supported   # noqa: E402
from repro.roofline.analysis import analyze                # noqa: E402

ASSIGNED = [a for a in ARCHS if not a.startswith("gpt2")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline_table.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not shape_supported(cfg, SHAPES[shape_name]):
                continue
            t0 = time.time()
            try:
                terms = analyze(cfg, shape_name, multi_pod=False)
                row = terms.row()
                row["derive_s"] = round(time.time() - t0, 1)
                rows.append(row)
            except Exception:
                traceback.print_exc()
                rows.append({"arch": arch, "shape": shape_name,
                             "error": True})
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
