"""Shared benchmark helpers: the reduced case-study setup (paper SSV at
CI scale) and CSV emission in ``name,us_per_call,derived`` format."""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.data import banking77, partition

# scale knobs (env-overridable so the full run can go bigger)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.06"))
ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "10"))
PAD_LEN = int(os.environ.get("REPRO_BENCH_PAD", "24"))
SEEDS = tuple(int(s) for s in os.environ.get(
    "REPRO_BENCH_SEEDS", "0").split(","))      # paper uses 0,1,42
# execution backend for every federated run (core/rounds.py dispatch)
BACKEND = os.environ.get("REPRO_BENCH_BACKEND", "sequential")


def case_study_setup(seed: int = 0, scale: Optional[float] = None,
                     class_skew: float = 0.0):
    cfg = gpt2_tiny()
    pub, tr, te = banking77.paper_splits(cfg.vocab_size, pad_len=PAD_LEN,
                                         seed=seed,
                                         scale=scale or SCALE)
    clients = partition.iid_partition(tr, 3, seed=seed)
    return cfg, pub, clients, te


def fed_config(framework: str, seed: int = 0, **kw) -> FedConfig:
    base = dict(framework=framework, backend=BACKEND, n_clients=3,
                rounds=ROUNDS, lora_rank=4, lora_alpha=32.0,
                lora_dropout=0.0, split_layer=2, kd_epochs=1, lr=1e-3,
                seed=seed)
    base.update(kw)
    return FedConfig(**base)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn: Callable, *args, reps: int = 3, warmup: int = 1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / reps * 1e6
