"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (charter d).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,table1,kernels,roofline]
    PYTHONPATH=src python -m benchmarks.run --only fig4 --backend spmd

Scale knobs via env: REPRO_BENCH_SCALE / REPRO_BENCH_ROUNDS /
REPRO_BENCH_SEEDS (paper seeds: 0,1,42); REPRO_BENCH_BACKEND (or
--backend) picks the federated execution backend (sequential | spmd).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ("kernels", "fig4", "table1", "fig3", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--backend", default=None,
                    choices=["sequential", "spmd"],
                    help="federated execution backend for fig3/fig4/table1")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(MODULES)
    if args.backend:
        from benchmarks import common
        common.BACKEND = args.backend

    print("name,us_per_call,derived")
    failures = 0
    t0 = time.time()
    for name in MODULES:
        if name not in only:
            continue
        try:
            if name == "kernels":
                from benchmarks import kernels_micro
                kernels_micro.run()
            elif name == "fig3":
                from benchmarks import fig3_accuracy
                fig3_accuracy.run()
            elif name == "fig4":
                from benchmarks import fig4_comm_comp
                fig4_comm_comp.run()
            elif name == "table1":
                from benchmarks import table1_overview
                table1_overview.run()
            elif name == "roofline":
                from benchmarks import roofline_table
                roofline_table.run()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}_FAILED,0.0,exception")
    print(f"bench_total_wall,{(time.time()-t0)*1e6:.0f},"
          f"{failures}_module_failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
