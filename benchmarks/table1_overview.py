"""Paper Table I reproduction: the qualitative star-ratings derived from
measured quantities (not hand-assigned).  More stars = more of the
quantity, matching the paper's convention."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.rounds import run_federated
from repro.core import metrics as M
from repro.core.tasks import task_logit_dim


def _stars(value, lo, hi, n=5):
    if hi <= lo:
        return 3
    f = (np.log10(max(value, 1e-9)) - np.log10(max(lo, 1e-9))) / (
        np.log10(max(hi, 1e-9)) - np.log10(max(lo, 1e-9)))
    return int(np.clip(round(1 + f * (n - 1)), 1, n))


def run():
    rows = {}
    for fw in ("fedllm", "kd", "split"):
        cfg, pub, clients, te = common.case_study_setup(seed=0)
        fed = common.fed_config(fw, rounds=3)
        res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                            eval_batch=64)
        rows[fw] = {
            "acc": res.final_accuracy,
            "comm": res.ledger.mean_client_bytes_per_round(),
            "comp": float(np.mean(res.client_flops)) / fed.rounds,
        }

    comms = [r["comm"] for r in rows.values()]
    comps = [r["comp"] for r in rows.values()]
    for fw, r in rows.items():
        acc_stars = "*" * (5 if r["acc"] == max(
            x["acc"] for x in rows.values()) else 3)
        comm_stars = "*" * _stars(r["comm"], min(comms), max(comms))
        comp_stars = "*" * _stars(r["comp"], min(comps), max(comps))
        common.emit(f"table1_{fw}", 0.0,
                    f"acc={acc_stars}({r['acc']:.3f})|"
                    f"comm={comm_stars}({r['comm']:.2e}B)|"
                    f"comp={comp_stars}({r['comp']:.2e}F)")

    # the paper's KD classification-vs-generative communication contrast
    cfg, pub, _, _ = common.case_study_setup(seed=0)
    n = len(pub["tokens"])
    cls = M.logit_bytes(n, task_logit_dim("classification", cfg.vocab_size))
    gen = M.logit_bytes(n * common.PAD_LEN,
                        task_logit_dim("generative", cfg.vocab_size))
    common.emit("table1_kd_cls_vs_gen_logit_bytes", 0.0,
                f"cls={cls:.2e}|gen={gen:.2e}|ratio={gen/cls:.0f}x")
    return rows


if __name__ == "__main__":
    run()
