"""CI bench-regression gate: diff a fresh ``BENCH_kernels.json``
(benchmarks/kernels_micro.py) against the committed
``BENCH_kernels.baseline.json`` and fail when any kernel's fwd or
fwd+bwd time regresses by more than the threshold (default +30%).

    PYTHONPATH=src:. python benchmarks/kernels_micro.py
    python benchmarks/check_bench_regression.py [--threshold 1.30]

Escape hatches (see .github/workflows/ci.yml):
- PR label ``bench-rebaseline`` or the workflow_dispatch ``rebaseline``
  input skip the gate for an intentional perf trade-off;
- ``--update`` rewrites the baseline from the fresh run — commit the
  result in the same PR (also the fix when the runner hardware
  generation changes and every kernel shifts together).

Kernels present only in the baseline fail the gate (coverage silently
disappearing is itself a regression); kernels present only in the fresh
run pass with a note — they join the baseline at the next ``--update``.
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(baseline: dict, fresh: dict, threshold: float):
    """Returns (regressions, missing, new) lists of report lines."""
    regressions, missing, new = [], [], []
    for name, base_us in sorted(baseline.items()):
        if name not in fresh:
            missing.append(f"  {name}: in baseline but not in fresh run")
            continue
        us = fresh[name]
        ratio = us / base_us if base_us else float("inf")
        if ratio > threshold:
            regressions.append(
                f"  {name}: {base_us:.1f}us -> {us:.1f}us "
                f"({(ratio - 1) * 100:+.1f}%, limit "
                f"{(threshold - 1) * 100:+.0f}%)")
    for name in sorted(set(fresh) - set(baseline)):
        new.append(f"  {name}: {fresh[name]:.1f}us (no baseline yet)")
    return regressions, missing, new


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_kernels.baseline.json")
    ap.add_argument("--fresh", default="BENCH_kernels.json")
    ap.add_argument("--threshold", type=float, default=1.30,
                    help="fail ratio: fresh/baseline above this fails "
                         "(1.30 = +30%%)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh run "
                         "instead of gating")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(fresh, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"rebaselined {args.baseline} from {args.fresh} "
              f"({len(fresh)} kernels)")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    regressions, missing, new = compare(baseline, fresh, args.threshold)
    if new:
        print(f"{len(new)} new kernel(s) without a baseline:")
        print("\n".join(new))
    if missing:
        print(f"{len(missing)} kernel(s) LOST from the bench:")
        print("\n".join(missing))
    if regressions:
        print(f"{len(regressions)} kernel(s) regressed beyond "
              f"{(args.threshold - 1) * 100:+.0f}%:")
        print("\n".join(regressions))
    if regressions or missing:
        print("\nIf this slowdown is an accepted trade-off (or the "
              "runner changed), rebaseline: apply the 'bench-rebaseline' "
              "PR label to skip the gate, run "
              "`python benchmarks/check_bench_regression.py --update`, "
              "and commit BENCH_kernels.baseline.json.")
        return 1
    print(f"bench-regression gate OK: {len(baseline)} kernels within "
          f"{(args.threshold - 1) * 100:+.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
