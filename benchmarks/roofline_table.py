"""Roofline table benchmark (charter g): reads the dry-run sweep JSON if
present (results/dryrun_all.json) and emits one CSV row per (arch x
shape) single-pod pair with the three roofline terms.

Full re-derivation (lower per-layer variants) is available via
``python -m benchmarks.roofline_table --derive`` — that's what populates
EXPERIMENTS.md SSRoofline; the default path keeps `-m benchmarks.run`
fast by reusing the sweep JSON's HLO cost numbers when available."""
from __future__ import annotations

import json
import os

from benchmarks import common
from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.roofline import hw
from repro.roofline.analysis import model_flops_for

SWEEP_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                          "roofline_table.json")


def run():
    if not os.path.exists(SWEEP_JSON):
        common.emit("roofline_table", 0.0,
                    "results/roofline_table.json missing - run "
                    "scripts/run_roofline.py first")
        return
    with open(SWEEP_JSON) as f:
        rows = json.load(f)
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}"
        dominant = r["dominant"]
        common.emit(name, 0.0,
                    f"tc={r['t_compute_s']*1e3:.2f}ms|"
                    f"tm={r['t_memory_s']*1e3:.2f}ms|"
                    f"tcoll={r['t_collective_s']*1e3:.2f}ms|"
                    f"dom={dominant}|useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    run()
