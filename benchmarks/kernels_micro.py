"""Kernel microbenchmarks.  On CPU the Pallas kernels run in interpret
mode (Python emulation — not a performance number), so the timed paths
are the jitted XLA reference implementations; kernel correctness is
asserted against them in the same pass.  On a real TPU the same harness
times the compiled Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ref

ON_TPU = jax.default_backend() == "tpu"


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # lora matmul
    M, K, N, r = 512, 1024, 512, 8
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    a = jax.random.normal(ks[2], (K, r)) * 0.05
    b = jax.random.normal(ks[3], (r, N)) * 0.05
    if ON_TPU:
        from repro.kernels.lora_matmul import lora_matmul
        fn = jax.jit(lambda *t: lora_matmul(*t, interpret=False))
    else:
        fn = jax.jit(ref.lora_matmul_ref)
    _, us = common.timed(lambda: jax.block_until_ready(fn(x, w, a, b)))
    flops = 2 * M * N * (K + r) + 2 * M * K * r
    common.emit("kernel_lora_matmul_512x1024x512_r8", us,
                f"{flops/us*1e-3:.1f}GFLOP/s")

    # flash attention
    BH, S, D = 8, 512, 64
    q = jax.random.normal(ks[4], (BH, S, D))
    k = jax.random.normal(ks[5], (BH, S, D))
    v = jax.random.normal(ks[6], (BH, S, D))
    if ON_TPU:
        from repro.kernels.flash_attention import flash_attention
        fa = jax.jit(lambda *t: flash_attention(*t, interpret=False))
    else:
        fa = jax.jit(lambda *t: ref.attention_ref(*t))
    _, us = common.timed(lambda: jax.block_until_ready(fa(q, k, v)))
    common.emit("kernel_flash_attention_8x512x64_causal", us,
                f"{2*2*BH*S*S*D/us*1e-3:.1f}GFLOP/s")

    # kd loss over a big vocab
    R, V = 256, 32_768
    t = jax.random.normal(ks[7], (R, V))
    s = t + 0.1 * jax.random.normal(ks[0], (R, V))
    fkd = jax.jit(lambda a_, b_: ref.kd_loss_rows_ref(a_, b_, 2.0))
    _, us = common.timed(lambda: jax.block_until_ready(fkd(t, s)))
    common.emit("kernel_kd_loss_256x32768_T2", us,
                f"{R*V*2*4/us*1e-3:.1f}GB/s_stream")

    # rglru scan
    B, S_, W = 4, 1024, 512
    aa = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S_, W)))
    bb = jax.random.normal(ks[2], (B, S_, W)) * 0.1
    h0 = jnp.zeros((B, W))
    fr = jax.jit(ref.rglru_scan_ref)
    _, us = common.timed(lambda: jax.block_until_ready(fr(aa, bb, h0)))
    common.emit("kernel_rglru_scan_4x1024x512", us,
                f"{B*S_*W/us:.1f}Melem/s")

    # rwkv6 scan
    BH2, S2, D2 = 8, 256, 64
    args = [jax.random.normal(jax.random.fold_in(ks[3], i), (BH2, S2, D2))
            for i in range(3)]
    lw = -jax.nn.softplus(jax.random.normal(ks[4], (BH2, S2, D2)))
    u = 0.1 * jax.random.normal(ks[5], (BH2, D2))
    fw = jax.jit(ref.rwkv6_scan_ref)
    _, us = common.timed(
        lambda: jax.block_until_ready(fw(args[0], args[1], args[2], lw, u)))
    common.emit("kernel_rwkv6_scan_8x256x64", us,
                f"{2*BH2*S2*D2*D2*2/us*1e-3:.1f}GFLOP/s")

    # quantize
    x2 = jax.random.normal(ks[6], (1024, 2048))
    fq = jax.jit(lambda t_: ref.quantize_rows_ref(t_, 8))
    _, us = common.timed(lambda: jax.block_until_ready(fq(x2)))
    common.emit("kernel_quantize_1024x2048_int8", us,
                f"{x2.size*4/us*1e-3:.1f}GB/s")


if __name__ == "__main__":
    run()
