"""Kernel microbenchmarks — forward AND fwd+bwd per kernel.

On CPU the Pallas kernels run in interpret mode (Python emulation — not
a performance number), so the timed paths are the jitted XLA reference
implementations; kernel correctness (including the custom_vjp backward
kernels) is asserted against them in the same pass.  On a real TPU the
same harness times the compiled Pallas kernels, and the backward rows
time the fused custom_vjp backward kernels.

Besides the CSV lines on stdout, emits ``BENCH_kernels.json``
(name -> us_per_call) so subsequent PRs have a perf trajectory to
regress against; CI uploads it as a workflow artifact.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ref

ON_TPU = jax.default_backend() == "tpu"
OUT_PATH = os.environ.get("REPRO_BENCH_KERNELS_OUT", "BENCH_kernels.json")

RESULTS: dict = {}


def record(name: str, us: float, derived: str = "") -> None:
    RESULTS[name] = round(us, 1)
    common.emit(name, us, derived)


def _time(fn, *args):
    _, us = common.timed(lambda: jax.block_until_ready(fn(*args)))
    return us


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # ---------------- lora matmul (fwd + fwd/bwd) ------------------------ #
    M, K, N, r = 512, 1024, 512, 8
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    a = jax.random.normal(ks[2], (K, r)) * 0.05
    b = jax.random.normal(ks[3], (r, N)) * 0.05
    if ON_TPU:
        from repro.kernels.lora_matmul import lora_matmul
        base = lambda *t: lora_matmul(*t, interpret=False)
    else:
        base = ref.lora_matmul_ref
    fwd = jax.jit(base)
    bwd = jax.jit(jax.grad(lambda *t: jnp.sum(base(*t)),
                           argnums=(0, 1, 2, 3)))
    flops = 2 * M * N * (K + r) + 2 * M * K * r
    us = _time(fwd, x, w, a, b)
    record("kernel_lora_matmul_512x1024x512_r8", us,
           f"{flops/us*1e-3:.1f}GFLOP/s")
    us = _time(bwd, x, w, a, b)
    record("kernel_lora_matmul_512x1024x512_r8_bwd", us,
           f"{3*flops/us*1e-3:.1f}GFLOP/s")

    # ---------------- flash attention (fwd + fwd/bwd) -------------------- #
    BH, S, D = 8, 512, 64
    q = jax.random.normal(ks[4], (BH, S, D))
    k = jax.random.normal(ks[5], (BH, S, D))
    v = jax.random.normal(ks[6], (BH, S, D))
    if ON_TPU:
        from repro.kernels.flash_attention import flash_attention
        fa = lambda *t: flash_attention(*t, interpret=False)
    else:
        fa = ref.attention_ref
    us = _time(jax.jit(fa), q, k, v)
    record("kernel_flash_attention_8x512x64_causal", us,
           f"{2*2*BH*S*S*D/us*1e-3:.1f}GFLOP/s")
    fa_bwd = jax.jit(jax.grad(lambda *t: jnp.sum(fa(*t)),
                              argnums=(0, 1, 2)))
    us = _time(fa_bwd, q, k, v)
    record("kernel_flash_attention_8x512x64_causal_bwd", us,
           f"{5*2*BH*S*S*D/us*1e-3:.1f}GFLOP/s")

    # ---------------- kd loss over a big vocab (fwd + fwd/bwd) ----------- #
    R, V = 256, 32_768
    t = jax.random.normal(ks[7], (R, V))
    s = t + 0.1 * jax.random.normal(ks[0], (R, V))
    if ON_TPU:
        from repro.kernels.kd_loss import kd_loss_rows
        fkd = lambda a_, b_: kd_loss_rows(a_, b_, temperature=2.0,
                                          interpret=False)
    else:
        fkd = lambda a_, b_: ref.kd_loss_rows_ref(a_, b_, 2.0)
    us = _time(jax.jit(fkd), t, s)
    record("kernel_kd_loss_256x32768_T2", us,
           f"{R*V*2*4/us*1e-3:.1f}GB/s_stream")
    fkd_bwd = jax.jit(jax.grad(lambda a_, b_: jnp.sum(fkd(a_, b_)),
                               argnums=(0, 1)))
    us = _time(fkd_bwd, t, s)
    record("kernel_kd_loss_256x32768_T2_bwd", us,
           f"{R*V*2*4*2/us*1e-3:.1f}GB/s_stream")

    # ---------------- rglru scan (fwd-only kernel) ----------------------- #
    B, S_, W = 4, 1024, 512
    aa = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S_, W)))
    bb = jax.random.normal(ks[2], (B, S_, W)) * 0.1
    h0 = jnp.zeros((B, W))
    us = _time(jax.jit(ref.rglru_scan_ref), aa, bb, h0)
    record("kernel_rglru_scan_4x1024x512", us, f"{B*S_*W/us:.1f}Melem/s")

    # ---------------- rwkv6 scan (fwd-only kernel) ----------------------- #
    BH2, S2, D2 = 8, 256, 64
    args = [jax.random.normal(jax.random.fold_in(ks[3], i), (BH2, S2, D2))
            for i in range(3)]
    lw = -jax.nn.softplus(jax.random.normal(ks[4], (BH2, S2, D2)))
    u = 0.1 * jax.random.normal(ks[5], (BH2, D2))
    us = _time(jax.jit(ref.rwkv6_scan_ref), args[0], args[1], args[2], lw, u)
    record("kernel_rwkv6_scan_8x256x64", us,
           f"{2*BH2*S2*D2*D2*2/us*1e-3:.1f}GFLOP/s")

    # ---------------- DP clip-scale-accumulate (fwd-only kernel) --------- #
    Bdp, Pdp = 16, 16_384
    gdp = jax.random.normal(ks[7], (Bdp, Pdp)) * 2.0
    if ON_TPU:
        from repro.kernels.dp_clip import dp_clip_mean_rows
        fdp = lambda t_: dp_clip_mean_rows(t_, clip=1.0, interpret=False)
    else:
        fdp = lambda t_: ref.clip_mean_rows_ref(t_, 1.0)
    us = _time(jax.jit(fdp), gdp)
    record("kernel_dp_clip_16x16384_c1", us,
           f"{Bdp*Pdp*4*2/us*1e-3:.1f}GB/s_stream")

    # ---------------- quantize + fused top-k ----------------------------- #
    x2 = jax.random.normal(ks[6], (1024, 2048))
    us = _time(jax.jit(lambda t_: ref.quantize_rows_ref(t_, 8)), x2)
    record("kernel_quantize_1024x2048_int8", us,
           f"{x2.size*4/us*1e-3:.1f}GB/s")
    if ON_TPU:
        from repro.kernels.quantize import topk_quantize_rows
        ftq = lambda t_: topk_quantize_rows(t_, k=32, interpret=False)
    else:
        ftq = lambda t_: ref.topk_quantize_rows_ref(t_, 32)
    us = _time(jax.jit(ftq), x2)
    record("kernel_topk_quantize_1024x2048_k32", us,
           f"{x2.size*4/us*1e-3:.1f}GB/s")

    with open(OUT_PATH, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
    print(f"# wrote {OUT_PATH} ({len(RESULTS)} entries)")


if __name__ == "__main__":
    run()
