"""Paper Fig. 4 reproduction: per-client per-round communication bytes
(log scale in the paper) and computation FLOPs for the three frameworks,
measured by the framework's own ledger/accounting — plus the privacy
overhead column: what DP-SGD + simulated secure aggregation add to each
framework's wire bill (secagg key/recovery exchange + DP metadata)."""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.configs.base import PrivacyConfig
from repro.core.rounds import run_federated

PRIVACY = PrivacyConfig(dp_clip=1.0, dp_noise_multiplier=0.5,
                        secure_agg=True)


def run():
    out = {}
    for fw, kw in (("fedllm", {}), ("kd", {}), ("split", {})):
        cfg, pub, clients, te = common.case_study_setup(seed=0)
        fed = common.fed_config(fw, rounds=2, **kw)
        res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                            eval_batch=64)
        comm = res.ledger.mean_client_bytes_per_round()
        flops = sum(res.client_flops) / len(res.client_flops) / fed.rounds
        out[fw] = (comm, flops)
        common.emit(f"fig4_{fw}_comm_bytes_per_client_round", 0.0,
                    f"{comm:.3e}")
        common.emit(f"fig4_{fw}_client_flops_per_round", 0.0, f"{flops:.3e}")
        # privacy-overhead column: same round under DP + secure-agg
        pres = run_federated(cfg, dataclasses.replace(fed, privacy=PRIVACY),
                             pub, clients, te, batch_size=16, eval_batch=64)
        n_cr = fed.rounds * fed.n_clients
        overhead = pres.ledger.privacy_overhead_bytes() / n_cr
        common.emit(f"fig4_{fw}_privacy_overhead_bytes_per_client_round",
                    0.0, f"{overhead:.3e}")
        common.emit(f"fig4_{fw}_privacy_epsilon", 0.0,
                    f"{pres.history[-1].epsilon:.3f}")

    # paper claims (SSIII / Fig 4)
    ok_comm = out["split"][0] > max(out["fedllm"][0], out["kd"][0])
    ok_comp = out["kd"][1] > out["fedllm"][1] > out["split"][1]
    common.emit("fig4_split_highest_comm", 0.0, "OK" if ok_comm else "VIOLATED")
    common.emit("fig4_kd_highest_compute_split_lowest", 0.0,
                "OK" if ok_comp else "VIOLATED")

    # rank scaling of FedLLM comm (paper: comm grows with r, compute ~flat)
    for r in (2, 8):
        cfg, pub, clients, te = common.case_study_setup(seed=0)
        fed = common.fed_config("fedllm", rounds=1, lora_rank=r)
        res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                            eval_batch=64)
        common.emit(f"fig4_fedllm_rank{r}_comm", 0.0,
                    f"{res.ledger.mean_client_bytes_per_round():.3e}")
    return out


if __name__ == "__main__":
    run()
