"""Paper Fig. 3 reproduction (reduced scale): model accuracy after N
rounds vs (a) LoRA rank r for FedLLMs, (b) public-dataset size for
KD-FedLLMs, (c) training samples per round for Split-FedLLMs — plus the
cross-framework accuracy ordering FedLLMs > {KD, Split} (SSIII.A)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.rounds import run_federated


def run(seeds=None):
    seeds = seeds or common.SEEDS
    rows = []

    def avg_acc(framework, seed_kw=None, setup_kw=None, **fed_kw):
        accs, t0 = [], time.perf_counter()
        for seed in seeds:
            cfg, pub, clients, te = common.case_study_setup(
                seed=seed, **(setup_kw or {}))
            fed = common.fed_config(framework, seed=seed, **fed_kw)
            res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                                eval_batch=64)
            accs.append(res.final_accuracy)
        us = (time.perf_counter() - t0) / max(len(seeds), 1) * 1e6
        return float(np.mean(accs)), us

    # (a) FedLLMs: accuracy vs LoRA rank
    rank_accs = {}
    for r in (2, 4, 8):
        acc, us = avg_acc("fedllm", lora_rank=r)
        rank_accs[r] = acc
        common.emit(f"fig3a_fedllm_rank{r}_acc", us, f"{acc:.4f}")

    # (b) KD-FedLLMs: accuracy vs public-dataset size
    pd_accs = {}
    for frac in (0.25, 1.0):
        cfg, pub, clients, te = common.case_study_setup(seed=seeds[0])
        n = max(16, int(len(pub["tokens"]) * frac))
        pub_f = {k: v[:n] for k, v in pub.items()}
        # 2 distillation epochs lift KD clear of chance at CI scale
        fed = common.fed_config("kd", seed=seeds[0], kd_epochs=2, lr=2e-3)
        res = run_federated(cfg, fed, pub_f, clients, te, batch_size=16,
                            eval_batch=64)
        pd_accs[frac] = res.final_accuracy
        common.emit(f"fig3b_kd_pd{int(frac*100)}pct_acc", 0.0,
                    f"{res.final_accuracy:.4f}")

    # (c) Split-FedLLMs: accuracy vs training samples per round
    ts_accs = {}
    for frac in (0.25, 1.0):
        cfg, pub, clients, te = common.case_study_setup(seed=seeds[0])
        cl = [{k: v[: max(8, int(len(v) * frac))] for k, v in c.items()}
              for c in clients]
        fed = common.fed_config("split", seed=seeds[0])
        res = run_federated(cfg, fed, pub, cl, te, batch_size=8,
                            eval_batch=64)
        ts_accs[frac] = res.final_accuracy
        common.emit(f"fig3c_split_ts{int(frac*100)}pct_acc", 0.0,
                    f"{res.final_accuracy:.4f}")

    # cross-framework ordering at the paper's default config
    acc_fed = rank_accs[8]
    acc_kd, _ = avg_acc("kd")
    acc_split = ts_accs[1.0]
    common.emit("fig3_ordering_fedllm_highest", 0.0,
                f"fedllm={acc_fed:.4f}|kd={acc_kd:.4f}|"
                f"split={acc_split:.4f}|"
                f"claim={'OK' if acc_fed >= max(acc_kd, acc_split) - 0.02 else 'VIOLATED'}")
    return {"rank": rank_accs, "pd": pd_accs, "ts": ts_accs,
            "ordering": (acc_fed, acc_kd, acc_split)}


if __name__ == "__main__":
    run()
