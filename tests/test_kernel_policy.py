"""KernelPolicy end-to-end: a full federated round for every framework
on both execution backends trains through the Pallas kernels under
``kernel_policy="pallas"`` (interpret mode on CPU) and produces ledger
bytes identical to the ``xla`` policy — the dispatch layer changes the
compute path, never the protocol."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core.rounds import run_federated
from repro.data import banking77, partition
from repro.kernels import ops

CFG = ModelConfig(name="policy-t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=192,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=64)


def _setup():
    pub = banking77.generate(24, CFG.vocab_size, 12, seed=0)
    tr = banking77.generate(48, CFG.vocab_size, 12, seed=1)
    te = banking77.generate(16, CFG.vocab_size, 12, seed=2)
    return pub, partition.iid_partition(tr, 2, seed=0), te


def test_policy_resolution():
    assert ops.resolve("xla") == "xla"
    assert ops.resolve("pallas") == "pallas"
    assert ops.resolve("auto") in ("xla", "pallas")
    with pytest.raises(ValueError):
        ops.resolve("cuda")
    with pytest.raises(ValueError):
        dataclasses.replace(CFG, kernel_policy="nope")
    assert not ops.use_pallas()                  # default ambient: xla
    with ops.policy_scope("pallas"):
        assert ops.use_pallas()
    assert not ops.use_pallas()


@pytest.mark.parametrize("backend", ["sequential", "spmd"])
@pytest.mark.parametrize("framework", ["fedllm", "kd", "split"])
def test_fed_round_pallas_matches_xla_ledger(framework, backend):
    pub, clients, te = _setup()
    fed = FedConfig(framework=framework, backend=backend, n_clients=2,
                    rounds=1, lora_rank=4, lora_dropout=0.0, split_layer=1,
                    seed=0)
    results = {}
    for policy in ("xla", "pallas"):
        cfg = dataclasses.replace(CFG, kernel_policy=policy)
        results[policy] = run_federated(cfg, fed, pub, clients, te,
                                        batch_size=8, eval_batch=8)
    xla, pal = results["xla"], results["pallas"]
    assert xla.ledger.total() == pal.ledger.total()
    assert xla.ledger.by_name() == pal.ledger.by_name()
    assert xla.ledger.per_client_round() == pal.ledger.per_client_round()
    for r in pal.history:
        assert np.isfinite(r.loss) and np.isfinite(r.accuracy)
    assert xla.client_flops == pal.client_flops


def test_kd_b3_compression_stays_on_device():
    """The b3 upload path must return device arrays (no host numpy)."""
    import jax

    from repro.core import kd
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 192)).astype(np.float32))
    for fed in (FedConfig(), FedConfig(logit_topk=8),
                FedConfig(logit_quant_bits=8),
                FedConfig(logit_topk=8, logit_quant_bits=8),
                FedConfig(logit_topk=8, logit_quant_bits=4)):
        out, wire = kd.compress_for_wire(logits, fed)
        assert isinstance(out, jax.Array), fed
        assert wire > 0


def test_logit_wire_bytes_matches_compress_for_wire():
    """The arithmetic b7 accounting must never drift from the actual
    b3 compression pipeline's reported wire size."""
    from repro.core import kd
    logits = jnp.asarray(np.random.default_rng(2).normal(
        size=(3, 16, 96)).astype(np.float32))
    for fed in (FedConfig(), FedConfig(logit_topk=8),
                FedConfig(logit_topk=500),           # topk >= dim: dense
                FedConfig(logit_quant_bits=8),
                FedConfig(logit_quant_bits=4),
                FedConfig(logit_topk=8, logit_quant_bits=8),
                FedConfig(logit_topk=8, logit_quant_bits=4)):
        _, wire = kd.compress_for_wire(logits, fed)
        assert kd.logit_wire_bytes(logits.shape, fed) == wire, fed


def test_fused_topk_quant_wire_accounting():
    """Fused top-k+int8/int4 wire bytes equal the packed payload size."""
    from repro.core import compression
    logits = jnp.asarray(np.random.default_rng(1).normal(
        size=(10, 96)).astype(np.float32))
    comp8, wire8 = compression.topk_quantize(logits, 8, bits=8)
    assert wire8 == comp8["values_q"].size + comp8["indices"].size * 4 \
        + 10 * 4
    comp4, wire4 = compression.topk_quantize(logits, 8, bits=4)
    assert comp4["values_q"].dtype == jnp.uint8
    assert wire4 == comp4["values_q"].size + comp4["indices"].size * 4 \
        + 10 * 4
    assert wire4 < wire8
    # reconstruction keeps the argmax (top-1 survives quantization)
    dense = compression.topk_dequantize(comp8)
    np.testing.assert_array_equal(np.asarray(dense.argmax(-1)),
                                  np.asarray(logits.argmax(-1)))
