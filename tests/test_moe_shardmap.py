"""shard_map MoE dispatch: multi-device equivalence vs the batched/global
paths (run in a subprocess so the forced device count never leaks into
other tests), plus the enc-dec Split-FedLLM boundary."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core import split
from repro.data import banking77
from repro.models.factory import build_model
from repro.peft import lora as lora_lib

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs.base import ModelConfig
    from repro.launch.mesh import activate_mesh
    from repro.models import moe

    for E, M in ((8, 2), (4, 4), (2, 4)):
        cfg_b = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                            n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=97,
                            n_experts=E, top_k=2, moe_capacity_factor=8.0,
                            moe_dispatch="batched")
        cfg_s = dataclasses.replace(cfg_b, moe_dispatch="shard_map")
        p = moe.init_moe(jax.random.PRNGKey(0), cfg_b)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5
        ob, ab = moe.moe_fwd(p, cfg_b, x)
        mesh = jax.make_mesh((8 // M, M), ("data", "model"))
        with activate_mesh(mesh):
            os_, as_ = jax.jit(lambda p, x: moe.moe_fwd(p, cfg_s, x))(p, x)
        np.testing.assert_allclose(np.asarray(ob), np.asarray(os_),
                                   rtol=5e-4, atol=5e-4)
        # aux uses per-shard load-balance stats pmean'd (E[xy] != E[x]E[y]):
        # the standard local approximation -- outputs exact, aux close
        np.testing.assert_allclose(float(ab), float(as_), rtol=0.15)
    print("SHARDMAP_EQUIV_OK")
""")


@pytest.mark.slow
def test_shard_map_moe_multidevice_equivalence():
    out = subprocess.run([sys.executable, "-c", SUBPROC], cwd="/root/repo",
                         capture_output=True, text=True, timeout=480)
    assert "SHARDMAP_EQUIV_OK" in out.stdout, out.stderr[-2000:]


def test_shard_map_falls_back_without_mesh():
    """On plain CPU (no mesh) shard_map configs must still run."""
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=97,
                      n_experts=4, top_k=2, moe_dispatch="shard_map")
    from repro.models import moe
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe.moe_fwd(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


def test_encdec_split_boundary():
    """Split-FedLLM on whisper-family: client=encoder, server=decoder."""
    cfg = ModelConfig(name="aud", family="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                      activation="gelu", norm="layernorm", use_rope=False,
                      max_position_embeddings=64, n_encoder_layers=2,
                      encoder_seq_len=8)
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    lt = lora_lib.init_lora(jax.random.PRNGKey(1), base,
                            ("wq", "wk", "wv"), 4)
    c_lt, s_lt = split.split_lora(lt, 0)
    assert "encoder" in c_lt and "encoder" not in s_lt
    base_c, base_s = split.split_base(base, 0, True)
    fed = FedConfig(framework="split", lora_rank=4, lora_dropout=0.0,
                    lr=5e-3)
    sfns = split.make_split_fns(model, fed, task="classification")
    d = banking77.generate(16, cfg.vocab_size, 12, seed=0)
    batch = {k: jnp.asarray(v) for k, v in d.items()}
    batch["enc_embeds"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (16, cfg.encoder_seq_len, cfg.d_model))
    c_opt, s_opt = sfns["opt_init"](c_lt), sfns["opt_init"](s_lt)
    losses = []
    for i in range(5):
        c_lt, s_lt, c_opt, s_opt, loss = sfns["split_train_step"](
            base_c, base_s, c_lt, s_lt, c_opt, s_opt, batch,
            jax.random.PRNGKey(i))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses
