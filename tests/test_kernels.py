"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(charter c: for each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kd_loss import kd_loss_rows
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.quantize import (quantize_pack4_rows, quantize_rows,
                                    topk_quantize_rows)
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("M,K,N,r", [(128, 256, 128, 4), (256, 512, 384, 8),
                                     (128, 1024, 256, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(M, K, N, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(M + N + r), 4)
    x = rand(ks[0], (M, K), dtype)
    w = rand(ks[1], (K, N), dtype, 0.05)
    a = rand(ks[2], (K, r), dtype, 0.05)
    b = rand(ks[3], (r, N), dtype, 0.05)
    out = lora_matmul(x, w, a, b, bm=128, bk=256, bn=128)
    expect = ref.lora_matmul_ref(x, w, a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("Sq,D,H,KV", [(128, 64, 4, 4), (256, 64, 4, 2),
                                       (256, 128, 8, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_sweep(Sq, D, H, KV, causal, window):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(Sq + D + window), 3)
    q = rand(ks[0], (B * H, Sq, D))
    k = rand(ks[1], (B * KV, Sq, D))
    v = rand(ks[2], (B * KV, Sq, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bkv=64)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    B, S, D = 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (rand(kk, (B, S, D), jnp.bfloat16) for kk in ks)
    out = flash_attention(q, k, v, bq=64, bkv=64)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("R,V,br,bv", [(64, 1024, 32, 256),
                                       (128, 4096, 64, 512),
                                       (32, 512, 32, 512)])
@pytest.mark.parametrize("T", [1.0, 2.0, 4.0])
def test_kd_loss_sweep(R, V, br, bv, T):
    ks = jax.random.split(jax.random.PRNGKey(R + V), 2)
    t = rand(ks[0], (R, V), scale=3.0)
    s = rand(ks[1], (R, V), scale=3.0)
    rows = kd_loss_rows(t, s, temperature=T, br=br, bv=bv)
    expect = ref.kd_loss_rows_ref(t, s, T)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_kd_loss_zero_when_identical():
    t = rand(jax.random.PRNGKey(3), (32, 2048), scale=5.0)
    rows = kd_loss_rows(t, t, temperature=2.0, br=32, bv=256)
    np.testing.assert_allclose(np.asarray(rows), 0.0, atol=1e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,W,bt,bw", [(2, 64, 128, 16, 128),
                                         (1, 128, 256, 64, 128),
                                         (3, 32, 128, 32, 64)])
def test_rglru_scan_sweep(B, S, W, bt, bw):
    ks = jax.random.split(jax.random.PRNGKey(B * S + W), 3)
    a = jax.nn.sigmoid(rand(ks[0], (B, S, W)))
    b = rand(ks[1], (B, S, W), scale=0.1)
    h0 = rand(ks[2], (B, W))
    h, hf = rglru_scan(a, b, h0, bw=bw, bt=bt)
    hr, hfr = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), rtol=1e-4,
                               atol=1e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("BH,S,D,bt", [(4, 32, 16, 16), (2, 64, 32, 32),
                                       (8, 16, 64, 16)])
def test_rwkv6_scan_sweep(BH, S, D, bt):
    ks = jax.random.split(jax.random.PRNGKey(BH + S + D), 5)
    r = rand(ks[0], (BH, S, D))
    k = rand(ks[1], (BH, S, D))
    v = rand(ks[2], (BH, S, D))
    lw = -jax.nn.softplus(rand(ks[3], (BH, S, D)))
    u = rand(ks[4], (BH, D), scale=0.1)
    y, Sf = rwkv6_scan(r, k, v, lw, u, bt=bt)
    yr, Sfr = ref.rwkv6_scan_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(Sfr), rtol=2e-4,
                               atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("R,C", [(8, 128), (16, 384), (32, 1000)])
@pytest.mark.parametrize("bits", [8])
def test_quantize_sweep(R, C, bits):
    x = rand(jax.random.PRNGKey(R + C), (R, C), scale=3.0)
    q, sc = quantize_rows(x, bits=bits, br=min(8, R))
    qr, scr = ref.quantize_rows_ref(x, bits)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(scr), rtol=1e-6)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("R,C", [(8, 128), (16, 384), (4, 1000)])
def test_quantize_pack4_roundtrip(R, C):
    """In-kernel nibble packing: two int4 per byte, exact unpack."""
    x = rand(jax.random.PRNGKey(R + C), (R, C), scale=3.0)
    packed, sc = quantize_pack4_rows(x, br=min(4, R))
    assert packed.dtype == jnp.uint8 and packed.shape == (R, C // 2)
    qr, scr = ref.quantize_rows_ref(x, 4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(scr), rtol=1e-6)
    unpacked = compression.unpack_int4(packed, C)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(qr))
    # jnp pack of the reference payload gives bit-identical bytes
    np.testing.assert_array_equal(
        np.asarray(compression.pack_int4(qr)), np.asarray(packed))


def test_pack_int4_odd_dim_roundtrip():
    q = jnp.asarray(np.random.default_rng(0).integers(-7, 8, (5, 9)),
                    jnp.int8)
    packed = compression.pack_int4(q)
    assert packed.shape == (5, 5)
    np.testing.assert_array_equal(
        np.asarray(compression.unpack_int4(packed, 9)), np.asarray(q))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("R,C,k", [(8, 128, 8), (16, 500, 16), (4, 64, 1)])
@pytest.mark.parametrize("bits", [8, 4])
def test_topk_quantize_sweep(R, C, k, bits):
    """Fused top-k+int row kernel == lax.top_k + symmetric quantization."""
    x = rand(jax.random.PRNGKey(R + C + k), (R, C), scale=3.0)
    q, idx, sc = topk_quantize_rows(x, k=k, bits=bits, br=min(4, R))
    qr, idxr, scr = ref.topk_quantize_rows_ref(x, k, bits)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idxr))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(scr), rtol=1e-6)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,cap,expect", [
    (151936, 2048, 128),      # qwen-style vocab: aligned divisor, not V
    (32768, 2048, 2048),      # power of two: the cap itself
    (512, 384, 256),          # aligned divisor under the cap
    (1000, 512, 500),         # no aligned divisor: largest plain divisor
    (77, 2048, 77),           # small classification head: whole dim
    (8191, 2048, 8191),       # prime: whole dim, never a width-1 grid
    (50257, 2048, 1733),      # gpt2 vocab: best plain divisor, not 1
])
def test_fit_block_aligned_divisors(n, cap, expect):
    got = ops.fit_block(n, cap)
    assert got == expect and n % got == 0


def test_kd_loss_nondivisible_vocab_streams_chunks():
    """V % bv != 0 must NOT fall back to a single whole-vocab block."""
    R, V = 16, 1000                               # bv=256 -> fit 250? no:
    bv = ops.fit_block(V, 256)                    # largest divisor <= 256
    assert bv < V and V % bv == 0
    t = rand(jax.random.PRNGKey(0), (R, V), scale=3.0)
    s = rand(jax.random.PRNGKey(1), (R, V), scale=3.0)
    loss = ops.kd_loss(t, s, temperature=2.0, br=16, bv=256)
    expect = jnp.mean(ref.kd_loss_rows_ref(t, s, 2.0))
    np.testing.assert_allclose(np.asarray(loss), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
def test_ops_wrappers_match_model_layouts():
    """ops.* handle model-native layouts (B,S,H,D) and padding."""
    B, S, H, KV, D = 2, 96, 4, 2, 32          # S=96 pads to 128-tile
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = rand(ks[0], (B, S, H * D))
    w = rand(ks[1], (H * D, 64), scale=0.1)
    a = rand(ks[2], (H * D, 4), scale=0.1)
    b = jnp.zeros((4, 64))
    out = ops.lora_matmul(x, w, a, b)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.lora_matmul_ref(
            x.reshape(-1, H * D), w, a, b)).reshape(B, S, 64),
        rtol=2e-4, atol=2e-4)
