"""Million-client rounds: ClientPopulation laziness, the cohort-
streaming executor's golden parity against the flat engines, and the
hierarchical (client->edge->server) ledger accounting.

Parity is the acceptance bar: ``backend="cohort"`` must report the
exact same CommLedger bytes as sequential/SPMD for every framework,
with metrics within fp32 tolerance — whether the cohort covers the
fleet (cohort_size >= n_clients) or streams it in chunks."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig, PrivacyConfig
from repro.core import metrics as M
from repro.core.rounds import run_federated
from repro.data import banking77, partition
from repro.data.population import (ClientPopulation, DirichletPopulation,
                                   EagerPopulation)

CFG = ModelConfig(name="pop-t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=192,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=64)

FRAMEWORKS = ("fedllm", "kd", "split")


@pytest.fixture(scope="module")
def case():
    pub = banking77.generate(24, CFG.vocab_size, 12, seed=0)
    tr = banking77.generate(96, CFG.vocab_size, 12, seed=1)
    te = banking77.generate(16, CFG.vocab_size, 12, seed=2)
    return pub, partition.iid_partition(tr, 4, seed=0), te


def _fed(**kw):
    base = dict(framework="fedllm", n_clients=4, rounds=2, lora_rank=4,
                lora_dropout=0.0, split_layer=1, kd_epochs=1, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _run(case, fed):
    pub, clients, te = case
    return run_federated(CFG, fed, pub,
                         ClientPopulation.from_clients_data(clients), te,
                         batch_size=8, eval_batch=16)


# --------------------------------------------------------------------------- #
# Golden parity: cohort executor vs the sequential reference, all
# frameworks x (cohort covers fleet, cohort streams fleet)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module", params=FRAMEWORKS)
def cohort_matrix(request, case):
    fw = request.param
    seq = _run(case, _fed(framework=fw))
    whole = _run(case, _fed(framework=fw, backend="cohort", cohort_size=8))
    chunked = _run(case, _fed(framework=fw, backend="cohort",
                              cohort_size=2))
    return fw, seq, {"cohort>=n": whole, "cohort<n": chunked}


def test_cohort_ledger_parity_exact(cohort_matrix):
    fw, seq, runs = cohort_matrix
    for tag, coh in runs.items():
        assert seq.ledger.per_round() == coh.ledger.per_round(), (fw, tag)
        assert seq.ledger.by_name() == coh.ledger.by_name(), (fw, tag)
        assert seq.ledger.per_client_round() == \
            coh.ledger.per_client_round(), (fw, tag)
        assert seq.ledger.total() == coh.ledger.total(), (fw, tag)


def test_cohort_metrics_parity(cohort_matrix):
    fw, seq, runs = cohort_matrix
    for tag, coh in runs.items():
        assert abs(seq.final_accuracy - coh.final_accuracy) <= 1e-3, \
            (fw, tag)
        for hs, hc in zip(seq.history, coh.history):
            assert abs(hs.loss - hc.loss) <= 1e-3, (fw, tag)
            assert abs(hs.accuracy - hc.accuracy) <= 1e-3, (fw, tag)


def test_cohort_flops_parity_exact(cohort_matrix):
    fw, seq, runs = cohort_matrix
    for tag, coh in runs.items():
        np.testing.assert_array_equal(np.asarray(seq.client_flops),
                                      np.asarray(coh.client_flops),
                                      err_msg=f"{fw}/{tag}")


def test_cohort_final_tree_close(cohort_matrix):
    fw, seq, runs = cohort_matrix
    for tag, coh in runs.items():
        for a, b in zip(jax.tree.leaves(seq.final_lora),
                        jax.tree.leaves(coh.final_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-4,
                                       err_msg=f"{fw}/{tag}")


# --------------------------------------------------------------------------- #
# Async + secure-agg + hetero compose with cohort streaming
# --------------------------------------------------------------------------- #
def test_cohort_async_parity(case):
    fed = _fed(aggregation="async", max_staleness=2, rounds=4)
    seq = _run(case, fed)
    coh = _run(case, dataclasses.replace(fed, backend="cohort",
                                         cohort_size=2))
    assert seq.ledger.per_client_round() == coh.ledger.per_client_round()
    assert abs(seq.final_accuracy - coh.final_accuracy) <= 1e-3


def test_cohort_secagg_payload_parity(case):
    """Per-chunk masking cohorts change the secagg key-exchange bytes
    (smaller cohorts, fewer pairs) but must leave every model-payload
    byte — and the mask-cancellation invariant — intact."""
    fed = _fed(privacy=PrivacyConfig(secure_agg=True))
    seq = _run(case, fed)
    coh = _run(case, dataclasses.replace(fed, backend="cohort",
                                         cohort_size=2))
    assert "secagg_keys" in coh.ledger.by_name()
    assert seq.ledger.payload_view().per_client_round() == \
        coh.ledger.payload_view().per_client_round()
    assert abs(seq.final_accuracy - coh.final_accuracy) <= 1e-3


def test_cohort_hetero_parity(case):
    fed = _fed(client_ranks=[4, 2, 4, 2])
    seq = _run(case, fed)
    coh = _run(case, dataclasses.replace(fed, backend="cohort",
                                         cohort_size=2))
    assert seq.ledger.per_client_round() == coh.ledger.per_client_round()
    for a, b in zip(jax.tree.leaves(seq.final_lora),
                    jax.tree.leaves(coh.final_lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


# --------------------------------------------------------------------------- #
# Hierarchical aggregation: client->edge / edge->server accounting
# --------------------------------------------------------------------------- #
def test_hierarchical_hop_accounting(case):
    fed = _fed(backend="cohort", cohort_size=2)
    flat = _run(case, fed)
    hier = _run(case, dataclasses.replace(fed, n_edges=2))
    # every per-client byte of the flat topology is the first hop of
    # the two-hop one — the hierarchical reduce's client-side total
    # matches the flat aggregation's bytes exactly
    assert hier.ledger.hop_total(M.CLIENT_EDGE) == flat.ledger.total()
    assert set(hier.ledger.by_hop()) == {M.CLIENT_EDGE, M.EDGE_SERVER}
    assert hier.ledger.hop_total(M.EDGE_SERVER) > 0
    # the edge->server hop is infrastructure: payload accounting and
    # the per-client mean are unchanged
    assert hier.ledger.payload_view().per_client_round() == \
        flat.ledger.payload_view().per_client_round()
    assert hier.history[-1].comm_bytes_per_client == \
        flat.history[-1].comm_bytes_per_client
    # and the model is the same convex combination
    for a, b in zip(jax.tree.leaves(flat.final_lora),
                    jax.tree.leaves(hier.final_lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_hierarchical_client_mean_matches_flat():
    from repro.core import fed_spmd
    k = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(k, (8, 3, 5)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (8, 7))}
    w = jax.numpy.asarray([1., 2., 3., 4., 5., 6., 7., 8.])
    flat = fed_spmd.weighted_client_mean(tree, w)
    for ne in (2, 4):
        hier = fed_spmd.hierarchical_client_mean(tree, w, ne)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)
    # non-divisible edge counts fall back to the flat reduce
    fb = fed_spmd.hierarchical_client_mean(tree, w, 3)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(fb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# ClientPopulation: laziness, determinism, cohort API
# --------------------------------------------------------------------------- #
def _base_data(n=120):
    d = banking77.generate(n, CFG.vocab_size, 12, seed=3)
    return d


def test_dirichlet_population_100k_is_lazy():
    """A 100k-virtual-client fleet must cost O(base data): no array
    anywhere in the population with a leading dim near the fleet size,
    and cohort materialization touches only the cohort."""
    base = _base_data()
    pop = DirichletPopulation(base, 100_000, alpha=0.5, seed=7,
                              shard_size=8)
    assert len(pop) == 100_000
    assert pop.n_cohorts(64) == 1563
    for arr in jax.tree.leaves(pop.__dict__):
        if isinstance(arr, np.ndarray):
            assert arr.shape[0] < 100_000
    c = pop.cohort(0, 1562, 64)            # the ragged last cohort
    assert c.clients[0] == 1562 * 64 and len(c) == 100_000 - 1562 * 64
    c0 = pop.cohort(0, 0, 64)
    assert len(c0) == 64
    # bitwise-deterministic regardless of materialization order
    again = pop.client(c0.clients[5])
    np.testing.assert_array_equal(c0.data[5]["tokens"], again["tokens"])
    with pytest.raises(IndexError):
        pop.cohort(0, 1563, 64)
    with pytest.raises(IndexError):
        pop[100_000]


def test_dirichlet_population_order_independent():
    base = _base_data()
    a = DirichletPopulation(base, 50, alpha=0.3, seed=11)
    b = DirichletPopulation(base, 50, alpha=0.3, seed=11)
    # touch b's clients in reverse order — shards must not move
    rev = {ci: b.client(ci) for ci in reversed(range(50))}
    for ci in range(0, 50, 7):
        fwd = a.client(ci)
        for k in fwd:
            np.testing.assert_array_equal(fwd[k], rev[ci][k])


def test_dirichlet_partition_delegates_to_population():
    """data/partition.dirichlet_partition is now the eager view of the
    same seeded fold-in derivation — bit-stable per client."""
    base = _base_data()
    parts = partition.dirichlet_partition(base, 6, alpha=0.5, seed=5)
    pop = DirichletPopulation(base, 6, alpha=0.5, seed=5)
    assert len(parts) == 6
    for ci in range(6):
        np.testing.assert_array_equal(parts[ci]["tokens"],
                                      pop.client(ci)["tokens"])


def test_eager_population_wraps_by_reference(case):
    _, clients, _ = case
    pop = ClientPopulation.from_clients_data(clients)
    assert isinstance(pop, EagerPopulation)
    assert len(pop) == len(clients)
    assert pop[2] is clients[2]
    assert pop.data_weights() == [len(d["tokens"]) for d in clients]


# --------------------------------------------------------------------------- #
# API shim: eager lists deprecate, populations are the way in
# --------------------------------------------------------------------------- #
def test_eager_list_shim_warns_population_does_not(case):
    pub, clients, te = case
    fed = _fed(rounds=1)
    with pytest.warns(DeprecationWarning):
        run_federated(CFG, fed, pub, clients, te, batch_size=8,
                      eval_batch=16)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        run_federated(CFG, fed, pub,
                      ClientPopulation.from_clients_data(clients), te,
                      batch_size=8, eval_batch=16)


def test_n_virtual_clients_mismatch_raises(case):
    pub, clients, te = case
    fed = _fed(n_virtual_clients=9)
    with pytest.raises(ValueError, match="n_virtual_clients"):
        run_federated(CFG, fed, pub,
                      ClientPopulation.from_clients_data(clients), te,
                      batch_size=8, eval_batch=16)
