"""Substrate unit tests: optimizer, schedules, data pipeline, checkpoint,
sharding policy (spec trees via AbstractMesh — no device state)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data import banking77, loader, partition
from repro.launch.sharding import ShardingPolicy
from repro.models.factory import build_model
from repro.optim import adam, schedule, sgd
from repro.peft import lora as lora_lib


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adam_matches_closed_form_first_step():
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st = adam.init(p)
    new_p, st = adam.update(g, st, p, lr=0.1)
    # first Adam step moves by ~lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, 2.0 + 0.1], rtol=1e-4)
    assert int(st["step"]) == 1


def test_adam_converges_quadratic():
    p = {"w": jnp.asarray(5.0)}
    st = adam.init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st = adam.update(g, st, p, lr=0.1)
    assert abs(float(p["w"])) < 1e-2


def test_sgd_momentum():
    p = {"w": jnp.asarray(1.0)}
    st = sgd.init(p, momentum=0.9)
    p1, st = sgd.update({"w": jnp.asarray(1.0)}, st, p, 0.1, momentum=0.9)
    p2, st = sgd.update({"w": jnp.asarray(1.0)}, st, p1, 0.1, momentum=0.9)
    assert float(p["w"] - p1["w"]) == pytest.approx(0.1, rel=1e-5)
    assert float(p1["w"] - p2["w"]) == pytest.approx(0.19, rel=1e-5)


def test_schedules():
    f = schedule.warmup_cosine(1.0, 10, 100)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-3)
    g = schedule.linear_decay(2.0, 100)
    assert float(g(50)) == pytest.approx(1.0)


# --------------------------------------------------------------------------- #
# data
# --------------------------------------------------------------------------- #
def test_banking77_deterministic_and_learnable():
    d1 = banking77.generate(100, 512, 32, seed=5)
    d2 = banking77.generate(100, 512, 32, seed=5)
    np.testing.assert_array_equal(d1["tokens"], d2["tokens"])
    assert d1["labels"].max() < 77
    # class keywords exist: same-label rows share tokens above chance
    same = d1["labels"][0] == d1["labels"]
    same[0] = False
    if same.any():
        row0 = set(d1["tokens"][0]) - {0}
        other = set(d1["tokens"][np.where(same)[0][0]]) - {0}
        assert row0 & other


def test_paper_splits_sizes():
    pub, tr, te = banking77.paper_splits(1024, scale=1.0)
    assert len(pub["tokens"]) == 5002
    assert len(tr["tokens"]) == 5001


def test_partitions():
    d = banking77.generate(300, 512, 16, seed=0)
    parts = partition.iid_partition(d, 3)
    assert sum(len(p["tokens"]) for p in parts) == 300
    niid = partition.dirichlet_partition(d, 3, alpha=0.1, seed=0)
    assert sum(len(p["tokens"]) for p in niid) >= 297
    # non-iid must be more label-skewed than iid
    def skew(ps):
        hists = [partition.label_histogram(p) for p in ps]
        return np.mean([np.abs(h - 1 / 77).sum() for h in hists])
    assert skew(niid) > skew(parts)


def test_loader_epoch():
    d = banking77.generate(50, 512, 16, seed=0)
    batches = list(loader.epoch_batches(d, 16, seed=0))
    assert len(batches) == 3
    assert all(len(b["tokens"]) == 16 for b in batches)


# --------------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": ({"c": jnp.ones((4,), jnp.bfloat16)},)}
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep_n=2)
        for s in (1, 2, 3, 4):
            cm.save(s, tree, {"step": s})
        assert cm.steps() == [3, 4]
        restored, meta = cm.restore(tree)
        assert meta["step"] == 4
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32))
            assert x.dtype == y.dtype


# --------------------------------------------------------------------------- #
# sharding policy (AbstractMesh: no devices needed)
# --------------------------------------------------------------------------- #
def _abstract_mesh():
    try:  # new jax: (sizes, names); old jax: ((name, size), ...) pairs
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


@pytest.mark.parametrize("arch,embed_spec", [
    ("qwen3-1.7b", P("model", None)),          # 151936 % 16 == 0
    ("whisper-base", P(None, "model")),        # 51865 % 16 != 0 -> d_model
])
def test_embed_fallback(arch, embed_spec):
    cfg = get_config(arch)
    policy = ShardingPolicy(_abstract_mesh(), cfg)
    model = build_model(cfg)
    shapes = model.init_abstract()
    specs = policy.tree_specs(shapes)
    assert specs["embed"] == embed_spec


def test_attention_col_row_rules():
    cfg = get_config("mistral-large-123b")
    policy = ShardingPolicy(_abstract_mesh(), cfg)
    model = build_model(cfg)
    specs = policy.tree_specs(model.init_abstract())
    blk = specs["blocks"][0]["attn"]
    assert blk["wq"] == P(None, None, "model")       # stacked col-parallel
    assert blk["wo"] == P(None, "model", None)       # stacked row-parallel


def test_moe_expert_sharding():
    cfg = get_config("qwen3-moe-235b-a22b")          # 128 experts % 16 == 0
    policy = ShardingPolicy(_abstract_mesh(), cfg)
    model = build_model(cfg)
    specs = policy.tree_specs(model.init_abstract())
    assert specs["blocks"][0]["mlp"]["w_in"] == P(None, "model", None, None)
    cfg2 = get_config("mixtral-8x7b")                # 8 experts -> ffn dim
    policy2 = ShardingPolicy(_abstract_mesh(), cfg2)
    specs2 = policy2.tree_specs(build_model(cfg2).init_abstract())
    assert specs2["blocks"][0]["mlp"]["w_in"] == P(None, None, None, "model")


def test_lora_specs_follow_base():
    cfg = get_config("qwen3-1.7b")
    policy = ShardingPolicy(_abstract_mesh(), cfg)
    model = build_model(cfg)
    shapes = model.init_abstract()
    lt = jax.eval_shape(lambda: lora_lib.init_lora(
        jax.random.PRNGKey(0), shapes, ("wq", "wo"), 8))
    specs = policy.tree_specs(lt)
    wq = specs["blocks"][0]["attn"]["wq"]
    assert wq["a"] == P() and wq["b"] == P(None, None, "model")
    wo = specs["blocks"][0]["attn"]["wo"]
    assert wo["a"] == P(None, "model", None) and wo["b"] == P()
