"""LoRA/PEFT unit tests: bind/merge equivalence, rank padding/truncation
scale preservation, heterogeneous aggregation, adapters, prompts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.heterogeneous import aggregate_hetero
from repro.core.fedavg import fedavg
from repro.models.factory import build_model
from repro.peft import adapters, lora, prompt

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=211)


@pytest.fixture(scope="module")
def setup():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1,
                                          CFG.vocab_size, jnp.int32)}
    return model, params, batch


def _nonzero_lora(params, rank=4, seed=7):
    lt = lora.init_lora(jax.random.PRNGKey(seed), params,
                        ("wq", "wk", "wv"), rank)
    return jax.tree.map(lambda x: x + 0.02, lt)


def test_bind_zero_b_is_identity(setup):
    model, params, batch = setup
    lt = lora.init_lora(jax.random.PRNGKey(2), params, ("wq",), 4)
    out0, _ = model.forward(params, batch)
    out1, _ = model.forward(lora.bind(params, lt, 32, 4), batch)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               atol=1e-5)


def test_bind_matches_merge(setup):
    model, params, batch = setup
    lt = _nonzero_lora(params)
    bound, _ = model.forward(lora.bind(params, lt, 32, 4), batch)
    merged, _ = model.forward(lora.merge(params, lt, 32, 4), batch)
    np.testing.assert_allclose(np.asarray(bound), np.asarray(merged),
                               rtol=2e-3, atol=2e-3)
    base, _ = model.forward(params, batch)
    assert float(jnp.abs(bound - base).max()) > 1e-4


def test_pad_rank_preserves_delta(setup):
    model, params, batch = setup
    lt4 = _nonzero_lora(params, rank=4)
    out4, _ = model.forward(lora.bind(params, lt4, 32, 4), batch)
    lt8 = lora.pad_rank(lt4, 8)
    out8, _ = model.forward(lora.bind(params, lt8, 32, 8), batch)
    np.testing.assert_allclose(np.asarray(out4), np.asarray(out8),
                               rtol=1e-4, atol=1e-4)


def test_lora_targets_rwkv():
    cfg = ModelConfig(name="r", family="ssm", n_layers=2, d_model=64,
                      n_heads=0, n_kv_heads=0, d_ff=96, vocab_size=211,
                      layer_pattern=("rwkv6",), head_dim=16)
    assert lora.default_targets(cfg) == lora.RWKV_TARGETS
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lt = lora.init_lora(jax.random.PRNGKey(1), params, lora.RWKV_TARGETS, 4)
    assert lora.n_params(lt) > 0


def test_hetero_zeropad_equals_fedavg_when_same_rank(setup):
    _, params, _ = setup
    trees = [_nonzero_lora(params, seed=s) for s in range(3)]
    agg_h = aggregate_hetero(trees, [4, 4, 4], 32.0, 4, method="zeropad")
    agg_f = fedavg(trees)
    for a, b in zip(jax.tree.leaves(agg_h), jax.tree.leaves(agg_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_hetero_svd_reconstructs_uniform_delta(setup):
    model, params, batch = setup
    lt = _nonzero_lora(params, rank=4)
    # three identical clients -> aggregate must equal each client's delta
    agg = aggregate_hetero([lt, lt, lt], [4, 4, 4], 32.0, 4, method="svd")
    out_lt, _ = model.forward(lora.bind(params, lt, 32, 4), batch)
    out_agg, _ = model.forward(lora.bind(params, agg, 32, 4), batch)
    np.testing.assert_allclose(np.asarray(out_lt), np.asarray(out_agg),
                               rtol=1e-3, atol=1e-3)


def test_dropout_mask_changes_output_deterministically(setup):
    model, params, batch = setup
    lt = _nonzero_lora(params)
    b1 = lora.bind(params, lt, 32, 4,
                   dropout_mask_rng=jax.random.PRNGKey(5), dropout=0.5)
    b2 = lora.bind(params, lt, 32, 4,
                   dropout_mask_rng=jax.random.PRNGKey(5), dropout=0.5)
    o1, _ = model.forward(b1, batch)
    o2, _ = model.forward(b2, batch)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    b3 = lora.bind(params, lt, 32, 4,
                   dropout_mask_rng=jax.random.PRNGKey(6), dropout=0.5)
    o3, _ = model.forward(b3, batch)
    assert float(jnp.abs(o1 - o3).max()) > 1e-6


def test_adapter_and_prompt_param_counts(setup):
    model, params, batch = setup
    ad = adapters.init_adapters(jax.random.PRNGKey(0), params, CFG.d_model,
                                bottleneck=8)
    n_ad = sum(x.size for x in jax.tree.leaves(ad))
    assert n_ad == CFG.n_layers * 2 * CFG.d_model * 8
    pr = prompt.init_prompt(jax.random.PRNGKey(1), CFG.d_model, 16)
    assert pr["prompt"].shape == (16, CFG.d_model)
