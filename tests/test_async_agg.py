"""Staleness-aware async aggregation (``FedConfig(aggregation="async")``,
core/async_agg.py): the seeded participation schedule is deterministic,
``max_staleness=0`` collapses the async engine onto the sync one
exactly, both execution backends report identical ledgers, async FedLLM
still converges on the synthetic task, and the staleness/heterogeneity
axes compose."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core import async_agg
from repro.core.rounds import run_federated
from repro.data import banking77, partition

CFG = ModelConfig(name="async-t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=192,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=64)


@pytest.fixture(scope="module")
def small_case():
    pub = banking77.generate(24, CFG.vocab_size, 12, seed=0)
    tr = banking77.generate(96, CFG.vocab_size, 12, seed=1)
    te = banking77.generate(32, CFG.vocab_size, 12, seed=2)
    return pub, partition.iid_partition(tr, 3, seed=0), te


def _fed(**kw):
    base = dict(framework="fedllm", n_clients=3, rounds=3, lora_rank=4,
                lora_dropout=0.0, split_layer=1, kd_epochs=1, seed=0,
                aggregation="async", max_staleness=3)
    base.update(kw)
    return FedConfig(**base)


# --------------------------------------------------------------------------- #
# Participation schedule + weights
# --------------------------------------------------------------------------- #
def test_schedule_deterministic_and_bounded():
    a = async_agg.ParticipationSchedule(5, seed=3, max_staleness=4)
    b = async_agg.ParticipationSchedule(5, seed=3, max_staleness=4)
    da = [[a.next_delay(ci) for _ in range(20)] for ci in range(5)]
    db = [[b.next_delay(ci) for _ in range(20)] for ci in range(5)]
    assert da == db
    assert all(0 <= d <= 5 for row in da for d in row)
    # per-client speed is a trait: some spread across clients
    assert len({tuple(row) for row in da}) > 1


def test_schedule_zero_staleness_is_synchronous():
    s = async_agg.ParticipationSchedule(4, seed=0, max_staleness=0)
    assert all(s.next_delay(ci) == 0 for ci in range(4) for _ in range(10))


def test_staleness_weight_polynomial_decay():
    assert async_agg.staleness_weight(0, 0.5) == 1.0
    assert async_agg.staleness_weight(3, 0.5) == pytest.approx(0.5)
    assert async_agg.staleness_weight(1, 2.0) == pytest.approx(0.25)
    w = [async_agg.staleness_weight(s, 0.7) for s in range(5)]
    assert w == sorted(w, reverse=True)


def test_unknown_aggregation_rejected(small_case):
    pub, clients, te = small_case
    fed = FedConfig(framework="fedllm", aggregation="buffered")
    with pytest.raises(ValueError, match="aggregation"):
        run_federated(CFG, fed, pub, clients, te, batch_size=8)


# --------------------------------------------------------------------------- #
# max_staleness=0 == sync, exactly (per framework, sequential backend)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("framework", ["fedllm", "kd", "split"])
def test_async_zero_staleness_equals_sync(small_case, framework):
    pub, clients, te = small_case
    fed = _fed(framework=framework, rounds=2, aggregation="sync")
    sync = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                         eval_batch=16)
    azync = run_federated(
        CFG, dataclasses.replace(fed, aggregation="async", max_staleness=0),
        pub, clients, te, batch_size=8, eval_batch=16)
    assert sync.ledger.per_client_round() == azync.ledger.per_client_round()
    assert sync.ledger.by_name() == azync.ledger.by_name()
    assert sync.client_flops == azync.client_flops
    for hs, ha in zip(sync.history, azync.history):
        assert hs.loss == ha.loss, framework
        assert hs.accuracy == ha.accuracy, framework


# --------------------------------------------------------------------------- #
# Real staleness: determinism, backend parity, convergence
# --------------------------------------------------------------------------- #
def test_async_deterministic_under_fixed_seed(small_case):
    pub, clients, te = small_case
    fed = _fed()
    a = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                      eval_batch=16)
    b = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                      eval_batch=16)
    assert [h.loss for h in a.history] == [h.loss for h in b.history]
    assert a.ledger.per_client_round() == b.ledger.per_client_round()
    for x, y in zip(np.asarray(a.client_flops), np.asarray(b.client_flops)):
        assert x == y


@pytest.mark.parametrize("framework", ["fedllm", "kd", "split"])
def test_async_backend_ledger_parity(small_case, framework):
    """Sequential and bucketed-SPMD async share one driver, so ledgers
    agree exactly and losses within fp32 tolerance."""
    pub, clients, te = small_case
    fed = _fed(framework=framework)
    seq = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                        eval_batch=16)
    spmd = run_federated(CFG, dataclasses.replace(fed, backend="spmd"),
                         pub, clients, te, batch_size=8, eval_batch=16)
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round()
    assert seq.ledger.by_name() == spmd.ledger.by_name()
    for hs, hp in zip(seq.history, spmd.history):
        assert abs(hs.loss - hp.loss) <= 1e-3, framework


def test_async_fedllm_converges_on_synthetic(small_case):
    pub, clients, te = small_case
    fed = _fed(rounds=8, lr=5e-3)
    res = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                        eval_batch=16)
    losses = [h.loss for h in res.history]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_async_stale_updates_arrive_late(small_case):
    """With real delays the upload of a round-r update lands in a later
    round: some round has no 'up' traffic at all, and totals across the
    run stay below the fully-synchronous byte count."""
    pub, clients, te = small_case
    fed = _fed(rounds=6)
    res = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                        eval_batch=16)
    sync = run_federated(CFG, dataclasses.replace(fed, aggregation="sync"),
                         pub, clients, te, batch_size=8, eval_batch=16)
    # every sync round moves every client's params both ways; async can't
    # move more than that, and stragglers mean it moves strictly less
    assert res.ledger.total() < sync.ledger.total()


def test_async_composes_with_hetero_ranks(small_case):
    """The two new workload axes compose: heterogeneous client ranks
    under async aggregation, identical ledger on both backends."""
    pub, clients, te = small_case
    fed = _fed(n_clients=3, lora_rank=8, client_ranks=(2, 4, 8),
               max_staleness=2)
    seq = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                        eval_batch=16)
    spmd = run_federated(CFG, dataclasses.replace(fed, backend="spmd"),
                         pub, clients, te, batch_size=8, eval_batch=16)
    assert np.isfinite(seq.history[-1].loss)
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round()
