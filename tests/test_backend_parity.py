"""Execution-backend parity: ``FedConfig(backend="spmd")`` must agree
with the sequential reference for every framework — final accuracy/loss
within fp32 tolerance (vmapped/batched reductions reorder float ops) and
the communication ledger byte-for-byte (all wire sizes are
shape-derived).  Dropout is 0 here: with dropout the backends draw
different (equally valid) mask streams and bit-level parity is
undefined (see core/rounds_spmd.py)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core.rounds import run_federated
from repro.data import banking77, partition

FRAMEWORKS = ("fedllm", "kd", "split")


@pytest.fixture(scope="module")
def case_study():
    cfg = gpt2_tiny()
    pub, tr, te = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                         scale=0.04)
    clients = partition.iid_partition(tr, 3)
    return cfg, pub, clients, te


@pytest.fixture(scope="module", params=FRAMEWORKS)
def both_backends(request, case_study):
    cfg, pub, clients, te = case_study
    fed = FedConfig(framework=request.param, n_clients=3, rounds=2,
                    lora_rank=4, lora_dropout=0.0, split_layer=2,
                    kd_epochs=1, seed=0)
    seq = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                        eval_batch=64)
    spmd = run_federated(cfg, dataclasses.replace(fed, backend="spmd"),
                         pub, clients, te, batch_size=16, eval_batch=64)
    return request.param, seq, spmd


def test_accuracy_and_loss_parity(both_backends):
    fw, seq, spmd = both_backends
    assert abs(seq.final_accuracy - spmd.final_accuracy) <= 1e-3, fw
    for hs, hp in zip(seq.history, spmd.history):
        assert abs(hs.loss - hp.loss) <= 1e-3, fw
        assert abs(hs.accuracy - hp.accuracy) <= 1e-3, fw


def test_ledger_bytes_parity_exact(both_backends):
    """Per-round, per-client and per-payload byte totals agree exactly:
    the SPMD backend must not change what the paper's Fig. 4 reports."""
    fw, seq, spmd = both_backends
    assert seq.ledger.per_round() == spmd.ledger.per_round(), fw
    assert seq.ledger.by_name() == spmd.ledger.by_name(), fw
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round(), fw
    assert seq.ledger.total() == spmd.ledger.total(), fw


def test_client_flops_parity_exact(both_backends):
    fw, seq, spmd = both_backends
    np.testing.assert_array_equal(np.asarray(seq.client_flops),
                                  np.asarray(spmd.client_flops), err_msg=fw)


def test_final_lora_trees_close(both_backends):
    """The aggregated parameters themselves agree within fp32 noise."""
    import jax

    fw, seq, spmd = both_backends
    ls, lp = jax.tree.leaves(seq.final_lora), jax.tree.leaves(spmd.final_lora)
    assert len(ls) == len(lp), fw
    for a, b in zip(ls, lp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4, err_msg=fw)


# --------------------------------------------------------------------------- #
# Heterogeneous LoRA ranks: bucketed SPMD vs sequential
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def hetero_case(case_study):
    cfg, pub, _, te = case_study
    _, tr, _ = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                      scale=0.04)
    return cfg, pub, partition.iid_partition(tr, 4), te


@pytest.fixture(scope="module", params=FRAMEWORKS)
def hetero_both_backends(request, hetero_case):
    cfg, pub, clients, te = hetero_case
    fed = FedConfig(framework=request.param, n_clients=4, rounds=1,
                    lora_rank=16, client_ranks=(4, 8, 8, 16),
                    lora_dropout=0.0, split_layer=2, kd_epochs=1, seed=0)
    seq = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                        eval_batch=64)
    spmd = run_federated(cfg, dataclasses.replace(fed, backend="spmd"),
                         pub, clients, te, batch_size=16, eval_batch=64)
    return request.param, seq, spmd


def test_hetero_ledger_and_flops_parity_exact(hetero_both_backends):
    """Per-rank bucketing must report the same rank-dependent wire bytes
    and client FLOPs as the sequential backend — Fig. 4 extends to the
    heterogeneous setting without a backend-dependent story."""
    fw, seq, spmd = hetero_both_backends
    assert seq.ledger.per_round() == spmd.ledger.per_round(), fw
    assert seq.ledger.by_name() == spmd.ledger.by_name(), fw
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round(), fw
    np.testing.assert_array_equal(np.asarray(seq.client_flops),
                                  np.asarray(spmd.client_flops), err_msg=fw)


def test_hetero_accuracy_parity(hetero_both_backends):
    fw, seq, spmd = hetero_both_backends
    for hs, hp in zip(seq.history, spmd.history):
        assert abs(hs.loss - hp.loss) <= 1e-3, fw
        assert abs(hs.accuracy - hp.accuracy) <= 1e-3, fw


def test_hetero_weak_clients_move_fewer_bytes(hetero_both_backends):
    """The whole point of rank truncation: a rank-4 client's param
    exchange costs ~1/4 of the rank-16 client's."""
    fw, _, spmd = hetero_both_backends
    if fw == "kd":
        pytest.skip("KD exchanges logits, not params — rank-independent")
    pcr = spmd.ledger.per_client_round()
    assert pcr[(0, 0)] < pcr[(0, 3)], fw


def test_hetero_svd_aggregation_spmd(hetero_case):
    """The svd harmonization path runs under bucketing too."""
    cfg, pub, clients, te = hetero_case
    fed = FedConfig(framework="fedllm", n_clients=4, rounds=1,
                    lora_rank=16, client_ranks=(4, 8, 8, 16),
                    hetero_agg="svd", lora_dropout=0.0, seed=0,
                    backend="spmd")
    res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                        eval_batch=64)
    assert np.isfinite(res.history[-1].loss)


def test_client_ranks_validation(case_study):
    cfg, pub, clients, te = case_study
    bad_len = FedConfig(framework="fedllm", client_ranks=(4, 8))
    with pytest.raises(ValueError, match="entries"):
        run_federated(cfg, bad_len, pub, clients, te, batch_size=16)
    too_big = FedConfig(framework="fedllm", lora_rank=8,
                        client_ranks=(4, 8, 16))
    with pytest.raises(ValueError, match="lora_rank"):
        run_federated(cfg, too_big, pub, clients, te, batch_size=16)


# --------------------------------------------------------------------------- #
# Cross-engine golden-parity matrix (unified RoundProgram pipeline):
# sequential vs spmd vs async(max_staleness=0), per framework, from one
# shared fixture — identical CommLedger bytes, fp32-tolerant metrics.
# --------------------------------------------------------------------------- #
def test_engine_matrix_golden_parity(both_backends, case_study):
    fw, seq, spmd = both_backends
    cfg, pub, clients, te = case_study
    fed = FedConfig(framework=fw, n_clients=3, rounds=2, lora_rank=4,
                    lora_dropout=0.0, split_layer=2, kd_epochs=1, seed=0,
                    aggregation="async", max_staleness=0)
    engines = {
        "async-seq": run_federated(cfg, fed, pub, clients, te,
                                   batch_size=16, eval_batch=64),
        "async-spmd": run_federated(
            cfg, dataclasses.replace(fed, backend="spmd"), pub, clients,
            te, batch_size=16, eval_batch=64),
    }
    for name, res in engines.items():
        key = (fw, name)
        # one pipeline -> one ledger, byte-for-byte
        assert res.ledger.per_round() == seq.ledger.per_round(), key
        assert res.ledger.by_name() == seq.ledger.by_name(), key
        assert res.ledger.per_client_round() == \
            seq.ledger.per_client_round(), key
        np.testing.assert_array_equal(np.asarray(res.client_flops),
                                      np.asarray(seq.client_flops),
                                      err_msg=str(key))
        for ha, hs in zip(res.history, seq.history):
            assert abs(ha.loss - hs.loss) <= 1e-3, key
            assert abs(ha.accuracy - hs.accuracy) <= 1e-3, key
    # the sequential async(0) engine collapses onto sync EXACTLY
    for ha, hs in zip(engines["async-seq"].history, seq.history):
        assert ha.loss == hs.loss, fw
        assert ha.accuracy == hs.accuracy, fw
    # spmd sync agrees with spmd async(0) within fp32 tolerance too
    for ha, hp in zip(engines["async-spmd"].history, spmd.history):
        assert abs(ha.loss - hp.loss) <= 1e-3, fw


def test_unknown_backend_rejected(case_study):
    cfg, pub, clients, te = case_study
    fed = FedConfig(framework="fedllm", backend="async")
    with pytest.raises(ValueError, match="backend"):
        run_federated(cfg, fed, pub, clients, te, batch_size=16)


def test_spmd_handles_ragged_client_data(case_study):
    """Clients with unequal batch counts run via the padded/masked scan
    and still produce the sequential backend's exact ledger."""
    cfg, pub, clients, te = case_study
    ragged = [
        {k: v[: 16 + 16 * ci] for k, v in c.items()}
        for ci, c in enumerate(clients)
    ]
    fed = FedConfig(framework="fedllm", n_clients=3, rounds=1, lora_rank=4,
                    lora_dropout=0.0, seed=0)
    seq = run_federated(cfg, fed, pub, ragged, te, batch_size=16,
                        eval_batch=64)
    spmd = run_federated(cfg, dataclasses.replace(fed, backend="spmd"),
                         pub, ragged, te, batch_size=16, eval_batch=64)
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round()
    assert seq.client_flops == spmd.client_flops
    assert abs(seq.final_accuracy - spmd.final_accuracy) <= 1e-3
