"""core/rng.py is the single source of truth for the engine's key
streams.  These tests pin every stream against the literal formulas the
pre-RoundProgram engines used (PR 4 state), so the dedupe can never
silently shift a stream — seeds, dropout masks and DP noise must replay
bit-identically across refactors."""
import dataclasses

import jax
import numpy as np

from repro.configs.base import FedConfig, PrivacyConfig
from repro.core import rng
from repro.privacy import dp


def _fed(**kw):
    base = dict(framework="fedllm", seed=3,
                privacy=PrivacyConfig(dp_clip=1.0, dp_noise_multiplier=0.5))
    base.update(kw)
    return FedConfig(**base)


def test_local_rng_pinned_to_legacy_formula():
    fed = _fed()
    for rnd in (0, 2, 7):
        for ci in (0, 1, 5):
            want = jax.random.PRNGKey(fed.seed * 1013 + rnd * 131 + ci)
            np.testing.assert_array_equal(
                np.asarray(rng.local_rng(fed, rnd, ci)), np.asarray(want))


def test_grid_keys_pinned_to_legacy_formula():
    """The (C, S) dropout grid the SPMD executor consumes is exactly
    split(local_rng) per row — the old rounds_spmd._grid_keys."""
    fed = _fed(seed=11)
    cis, n_steps = [0, 2, 5], 4
    grid = rng.grid_keys(fed, 3, cis, n_steps)
    for k, ci in enumerate(cis):
        want = jax.random.split(
            jax.random.PRNGKey(fed.seed * 1013 + 3 * 131 + ci), n_steps)
        np.testing.assert_array_equal(np.asarray(grid[k]),
                                      np.asarray(want))


def test_async_agg_alias_is_the_shared_helper():
    from repro.core import async_agg
    fed = _fed()
    np.testing.assert_array_equal(
        np.asarray(async_agg._local_rng(fed, 4, 2)),
        np.asarray(rng.local_rng(fed, 4, 2)))


def test_noise_key_pinned_to_legacy_fold_chain():
    """privacy/dp.noise_key through core/rng.fold_chain reproduces the
    PR 4 fold_in chain: PRNGKey(seed) -> 0x5EC7 -> privacy.seed -> rnd
    -> ci -> step."""
    fed = _fed(seed=5, privacy=PrivacyConfig(dp_clip=1.0,
                                             dp_noise_multiplier=0.5,
                                             seed=9))
    for rnd, ci, step in ((0, 0, 0), (2, 1, 3), (7, 5, 1)):
        key = jax.random.fold_in(jax.random.PRNGKey(fed.seed), 0x5EC7)
        key = jax.random.fold_in(key, fed.privacy.seed)
        key = jax.random.fold_in(key, rnd)
        key = jax.random.fold_in(key, ci)
        key = jax.random.fold_in(key, step)
        np.testing.assert_array_equal(
            np.asarray(dp.noise_key(fed, rnd, ci, step)), np.asarray(key))


def test_fold_chain_is_fold_in_composition():
    k0 = jax.random.PRNGKey(0)
    want = jax.random.fold_in(jax.random.fold_in(k0, 3), 7)
    np.testing.assert_array_equal(np.asarray(rng.fold_chain(k0, 3, 7)),
                                  np.asarray(want))


def test_streams_distinct_across_seeds():
    a = rng.local_rng(_fed(seed=0), 1, 1)
    b = rng.local_rng(_fed(seed=1), 1, 1)
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    na = dp.noise_key(_fed(seed=0), 1, 1)
    da = rng.local_rng(_fed(seed=0), 1, 1)
    # privacy noise and dropout streams are domain-separated
    assert not np.array_equal(np.asarray(na), np.asarray(da))


def test_noise_key_grid_builds_on_same_chain():
    fed = dataclasses.replace(_fed(), seed=2)
    grid = dp.noise_key_grid(fed, 1, [0, 3], 2)
    for k, ci in enumerate([0, 3]):
        for s in range(2):
            np.testing.assert_array_equal(
                np.asarray(grid[k, s]),
                np.asarray(dp.noise_key(fed, 1, ci, s)))
