"""Hypothesis property-based tests on system invariants (charter c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis extra "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression
from repro.core.fedavg import fedavg
from repro.kernels import ref
from repro.models import common, rwkv6
from repro.optim import clip

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats = st.floats(-50.0, 50.0, allow_nan=False, width=32)


# --------------------------------------------------------------------------- #
# Quantization: round-trip error bounded by scale/2 per element
# --------------------------------------------------------------------------- #
@given(st.integers(1, 6), st.integers(2, 64), st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(rows, cols, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * 5.0
    comp, _ = compression.quantize(x, 8)
    deq = compression.dequantize(comp)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.asarray(comp["scale"]) * 0.5 + 1e-6
    assert (err <= bound).all()


@given(st.integers(2, 40), st.integers(0, 2**31 - 1))
def test_quantize_preserves_sign_and_zero(cols, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, cols))
    x = x.at[:, 0].set(0.0)
    comp, _ = compression.quantize(x, 8)
    deq = np.asarray(compression.dequantize(comp))
    assert (deq[:, 0] == 0).all()
    big = np.abs(np.asarray(x)) > np.asarray(comp["scale"])[..., 0:1]
    assert (np.sign(deq)[big] == np.sign(np.asarray(x))[big]).all()


# --------------------------------------------------------------------------- #
# Top-k compression: exact on the transmitted support
# --------------------------------------------------------------------------- #
@given(st.integers(4, 64), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_topk_exact_on_support(V, k, seed):
    k = min(k, V)
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, V))
    comp, wire = compression.topk_compress(x, k)
    dense = compression.topk_decompress(comp)
    vals, idx = comp["values"], comp["indices"]
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(dense), np.asarray(idx), -1),
        np.asarray(vals))
    assert wire == vals.size * 8
    # argmax preserved
    np.testing.assert_array_equal(np.argmax(np.asarray(dense), -1),
                                  np.argmax(np.asarray(x), -1))


# --------------------------------------------------------------------------- #
# FedAvg: identity, convexity, weight normalization
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_fedavg_identity(seed, n):
    t = {"a": jax.random.normal(jax.random.PRNGKey(seed), (4, 3))}
    agg = fedavg([t] * n)
    np.testing.assert_allclose(np.asarray(agg["a"]), np.asarray(t["a"]),
                               rtol=1e-6)


@given(st.integers(0, 2**31 - 1),
       st.lists(st.floats(0.1, 10.0), min_size=2, max_size=4))
def test_fedavg_convex_bounds(seed, weights):
    trees = [{"a": jax.random.normal(jax.random.PRNGKey(seed + i), (5,))}
             for i in range(len(weights))]
    agg = fedavg(trees, weights)["a"]
    stack = np.stack([np.asarray(t["a"]) for t in trees])
    assert (np.asarray(agg) <= stack.max(0) + 1e-6).all()
    assert (np.asarray(agg) >= stack.min(0) - 1e-6).all()


@given(st.integers(0, 2**31 - 1), st.floats(0.5, 8.0))
def test_fedavg_weight_scale_invariance(seed, scale):
    trees = [{"a": jax.random.normal(jax.random.PRNGKey(seed + i), (5,))}
             for i in range(3)]
    w = [1.0, 2.0, 3.0]
    a1 = fedavg(trees, w)["a"]
    a2 = fedavg(trees, [x * scale for x in w])["a"]
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5)


# --------------------------------------------------------------------------- #
# RG-LRU: associative scan == sequential recurrence for any gates
# --------------------------------------------------------------------------- #
@given(st.integers(1, 3), st.integers(2, 32), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_rglru_associative_matches_sequential(B, S, W, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W)) * 0.3
    h0 = jnp.zeros((B, W))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h_assoc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_seq, _ = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h_assoc), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
# KD loss: KL >= 0 and == 0 iff identical logits (up to shift)
# --------------------------------------------------------------------------- #
@given(st.integers(2, 64), st.integers(0, 2**31 - 1),
       st.floats(0.5, 5.0))
def test_kd_kl_nonneg_and_shift_invariant(V, seed, T):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    t = jax.random.normal(ks[0], (4, V)) * 3
    s = jax.random.normal(ks[1], (4, V)) * 3
    kl = ref.kd_loss_rows_ref(t, s, T)
    assert (np.asarray(kl) >= -1e-5).all()
    kl_shift = ref.kd_loss_rows_ref(t + 7.0, s - 3.0, T)
    np.testing.assert_allclose(np.asarray(kl), np.asarray(kl_shift),
                               rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------- #
# Gradient clipping: norm after clip <= max_norm
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 10.0))
def test_clip_bounds_norm(seed, max_norm):
    t = {"a": jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 10}
    clipped, pre = clip.clip_by_global_norm(t, max_norm)
    post = float(clip.global_norm(clipped))
    assert post <= max_norm * (1 + 1e-4)
    if float(pre) <= max_norm:
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(t["a"]), rtol=1e-5)


# --------------------------------------------------------------------------- #
# WKV: decay == 0 reduces to cumulative outer-product attention
# --------------------------------------------------------------------------- #
@given(st.integers(2, 16), st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_wkv_no_decay_is_cumsum(S, D, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    r = jax.random.normal(ks[0], (1, S, 1, D))
    k = jax.random.normal(ks[1], (1, S, 1, D))
    v = jax.random.normal(ks[2], (1, S, 1, D))
    logw = jnp.zeros((1, S, 1, D))                      # w == 1: no decay
    u = jnp.zeros((1, D))
    y, _ = rwkv6.wkv_ref(r, k, v, logw, u)
    # manual: y_t = r_t @ sum_{j<t} k_j v_j^T
    S_run = np.zeros((D, D), np.float32)
    for t in range(S):
        expect = np.asarray(r[0, t, 0]) @ S_run
        np.testing.assert_allclose(np.asarray(y[0, t, 0]), expect,
                                   rtol=2e-3, atol=2e-3)
        S_run += np.outer(np.asarray(k[0, t, 0]), np.asarray(v[0, t, 0]))


# --------------------------------------------------------------------------- #
# Heterogeneous-rank normalization: the single helper behind every
# rank-dependent code path (core/heterogeneous.normalize_ranks)
# --------------------------------------------------------------------------- #
@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_normalize_ranks_properties(n_clients, lora_rank, seed):
    from repro.core import fed_spmd
    from repro.core.heterogeneous import normalize_ranks

    rng = np.random.default_rng(seed)
    # empty/None -> every client at the global rank
    assert normalize_ranks(None, n_clients, lora_rank) == \
        [lora_rank] * n_clients
    assert normalize_ranks((), n_clients, lora_rank) == \
        [lora_rank] * n_clients
    # valid assignment passes through as a list
    ranks = tuple(int(r) for r in rng.integers(1, lora_rank + 1, n_clients))
    out = normalize_ranks(ranks, n_clients, lora_rank)
    assert out == list(ranks)
    assert all(1 <= r <= lora_rank for r in out)
    # degenerate lengths: shorter AND longer both rejected
    with pytest.raises(ValueError, match="entries"):
        normalize_ranks(ranks + (1,), n_clients, lora_rank)
    if n_clients > 1:
        with pytest.raises(ValueError, match="entries"):
            normalize_ranks(ranks[:-1], n_clients, lora_rank)
    # out-of-range ranks rejected (never exceed the global rank)
    with pytest.raises(ValueError, match="lora_rank"):
        normalize_ranks((lora_rank + 1,) + ranks[1:], n_clients, lora_rank)
    with pytest.raises(ValueError, match="lora_rank"):
        normalize_ranks((0,) + ranks[1:], n_clients, lora_rank)
    # all-equal ranks collapse to ONE bucket and ONE contiguous segment
    eq = normalize_ranks((lora_rank,) * n_clients, n_clients, lora_rank)
    assert fed_spmd.rank_buckets(eq) == [(lora_rank, list(range(n_clients)))]
    assert fed_spmd.rank_segments(eq) == \
        [(lora_rank, list(range(n_clients)))]
    # bucketing partitions the client set, order preserved within buckets
    buckets = fed_spmd.rank_buckets(out)
    got = sorted(ci for _, cis in buckets for ci in cis)
    assert got == list(range(n_clients))
    for _, cis in buckets:
        assert cis == sorted(cis)
