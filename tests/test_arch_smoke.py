"""Per-architecture smoke tests (charter f): a REDUCED variant of each
assigned family (<=2-3 layers, d_model<=512, <=4 experts) runs one forward
and one LoRA train step on CPU; output shapes asserted, no NaNs.
Sub-quadratic archs (and the enc-dec) also run one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS, get_config
from repro.core.fedavg import make_fns
from repro.models.factory import build_model
from repro.peft import lora as lora_lib

ASSIGNED = [a for a in ARCHS if not a.startswith("gpt2")]
B, S = 2, 32


def smoke_batch(cfg, key=None, batch=B, seq=S):
    key = key or jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 1,
                                     cfg.vocab_size, jnp.int32),
        "lengths": jnp.full((batch,), seq, jnp.int32),
        "labels": jax.random.randint(ks[1], (batch,), 0, 77, jnp.int32),
    }
    if cfg.n_image_tokens:
        out["img_embeds"] = 0.02 * jax.random.normal(
            ks[2], (batch, cfg.n_image_tokens, cfg.image_embed_dim))
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = 0.02 * jax.random.normal(
            ks[2], (batch, cfg.encoder_seq_len, cfg.d_model))
    return out


@pytest.fixture(scope="module", params=ASSIGNED)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_forward_shapes_finite(arch_setup):
    name, cfg, model, params = arch_setup
    batch = smoke_batch(cfg)
    logits, aux = model.forward(params, batch)
    extra = cfg.n_image_tokens if cfg.n_image_tokens else 0
    assert logits.shape == (B, S + extra, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    assert np.isfinite(float(aux))


def test_train_step_updates_lora(arch_setup):
    name, cfg, model, params = arch_setup
    fed = FedConfig(lora_rank=4, lora_dropout=0.0,
                    lora_targets=lora_lib.default_targets(cfg))
    fns = make_fns(model, fed, task="generative")
    lt = lora_lib.init_lora(jax.random.PRNGKey(1), params,
                            fed.lora_targets, fed.lora_rank)
    assert lora_lib.n_params(lt) > 0, f"no LoRA targets matched for {name}"
    opt = fns["opt_init"](lt)
    batch = smoke_batch(cfg)
    lt2, opt2, loss = fns["train_step"](params, lt, opt, batch,
                                        jax.random.PRNGKey(2))
    assert np.isfinite(float(loss)), name
    # B starts at zero -> after one step it must have moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(lt), jax.tree.leaves(lt2)))
    assert moved, f"LoRA params did not update for {name}"


def test_decode_step(arch_setup):
    name, cfg, model, params = arch_setup
    batch = smoke_batch(cfg)
    cache = model.init_cache(params, B, 64, batch, dtype=jnp.float32)
    tok = batch["tokens"][:, 0]
    logits, cache = model.decode_step(params, cache, tok, jnp.asarray(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    logits2, _ = model.decode_step(params, cache, tok, jnp.asarray(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), name


def test_param_count_close_to_nameplate():
    expected = {
        "mistral-large-123b": 123e9, "qwen3-moe-235b-a22b": 235e9,
        "mixtral-8x7b": 46.7e9, "nemotron-4-340b": 340e9,
        "qwen2-1.5b": 1.5e9, "qwen3-1.7b": 1.7e9, "rwkv6-1.6b": 1.6e9,
        "llava-next-34b": 34e9, "recurrentgemma-2b": 2.7e9,
        "whisper-base": 0.074e9,
    }
    for arch, nameplate in expected.items():
        n = get_config(arch).param_count()
        assert 0.55 * nameplate < n < 1.45 * nameplate, (arch, n)
