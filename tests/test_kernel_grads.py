"""Gradient-parity sweeps for the differentiable Pallas kernels:
``jax.grad`` through the ops-layer wrappers (custom_vjp backward
kernels, interpret mode) must match ``jax.grad`` through the pure-jnp
oracles in kernels/ref.py within fp32 tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = dict(rtol=2e-3, atol=2e-4)


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def assert_grads_close(f_kernel, f_ref, args, names):
    np.testing.assert_allclose(np.asarray(f_kernel(*args)),
                               np.asarray(f_ref(*args)), **TOL)
    argnums = tuple(range(len(args)))
    gk = jax.grad(f_kernel, argnums=argnums)(*args)
    gr = jax.grad(f_ref, argnums=argnums)(*args)
    for name, a, b in zip(names, gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL,
                                   err_msg=name)


# --------------------------------------------------------------------------- #
# LoRA matmul: dx / dW / dA / dB, ranks {4, 8, 16}
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("r", [4, 8, 16])
def test_lora_matmul_grad_parity(r):
    B, S, K, N = 2, 48, 96, 64                 # B*S=96 pads to the 96-tile
    ks = jax.random.split(jax.random.PRNGKey(r), 5)
    x = rand(ks[0], (B, S, K))
    w = rand(ks[1], (K, N), 0.05)
    a = rand(ks[2], (K, r), 0.05)
    b = rand(ks[3], (r, N), 0.05)
    probe = rand(ks[4], (B, S, N))

    def f_kernel(x, w, a, b):
        return jnp.sum(ops.lora_matmul(x, w, a, b) * probe)

    def f_ref(x, w, a, b):
        y = ref.lora_matmul_ref(x.reshape(-1, K), w, a, b)
        return jnp.sum(y.reshape(B, S, N) * probe)

    assert_grads_close(f_kernel, f_ref, (x, w, a, b), "x w a b".split())


# --------------------------------------------------------------------------- #
# KD loss: masked rows, temperatures
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("T", [1.0, 2.0])
@pytest.mark.parametrize("masked", [False, True])
def test_kd_loss_grad_parity(T, masked):
    B, S, V = 2, 24, 384
    ks = jax.random.split(jax.random.PRNGKey(int(T) + masked), 3)
    t = rand(ks[0], (B, S, V), 3.0)
    s = rand(ks[1], (B, S, V), 3.0)
    mask = (jax.random.uniform(ks[2], (B, S)) > 0.3).astype(jnp.float32) \
        if masked else None

    def f_kernel(t, s):
        return ops.kd_loss(t, s, temperature=T, mask=mask, br=16, bv=128)

    def f_ref(t, s):
        rows = ref.kd_loss_rows_ref(t.reshape(-1, V), s.reshape(-1, V),
                                    T)[:, 0]
        if mask is None:
            return jnp.mean(rows)
        m = mask.reshape(-1)
        return jnp.sum(rows * m) / jnp.maximum(jnp.sum(m), 1.0)

    assert_grads_close(f_kernel, f_ref, (t, s), ("teacher", "student"))


def test_kd_loss_grad_chunk_fallback_nondivisible_vocab():
    """V % bv != 0 must stream aligned chunks, not one whole-vocab block,
    and the backward must agree with the reference either way."""
    R, V = 16, 384 + 128                        # 512 = 4 x 128, bv=384
    assert V % 384 != 0
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    t, s = rand(ks[0], (R, V), 2.0), rand(ks[1], (R, V), 2.0)

    def f_kernel(t, s):
        return ops.kd_loss(t, s, temperature=2.0, br=16, bv=384)

    def f_ref(t, s):
        return jnp.mean(ref.kd_loss_rows_ref(t, s, 2.0))

    assert_grads_close(f_kernel, f_ref, (t, s), ("teacher", "student"))


# --------------------------------------------------------------------------- #
# Flash attention: causal / windowed / noncausal, GQA
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32),
                                           (False, 0)])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2)])
def test_attention_grad_parity(causal, window, H, KV):
    B, S, D = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(window + H + KV), 4)
    q = rand(ks[0], (B, S, H, D))
    k = rand(ks[1], (B, S, KV, D))
    v = rand(ks[2], (B, S, KV, D))
    probe = rand(ks[3], (B, S, H, D))

    def f_kernel(q, k, v):
        out = ops.mha_attention(q, k, v, causal=causal, window=window,
                                bq=32, bkv=32)
        return jnp.sum(out * probe)

    def f_ref(q, k, v):
        flat = lambda x, n: x.transpose(0, 2, 1, 3).reshape(B * n, S, D)
        out = ref.attention_ref(flat(q, H), flat(k, KV), flat(v, KV),
                                causal=causal, window=window)
        out = out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
        return jnp.sum(out * probe)

    assert_grads_close(f_kernel, f_ref, (q, k, v), "qkv")
