"""Privacy subsystem (src/repro/privacy/, PrivacyConfig):

- secure-agg mask cancellation is *bit-exact* against the plain engines
  for every framework x backend x aggregation combination, and the
  privacy-overhead ledger bytes are identical across backends;
- DP runs are seed-deterministic and hold backend parity (identical
  ledger bytes; identical noise via the per-client fold_in keys);
- the fused clip-scale-accumulate kernel matches the XLA reference and
  the stacked-tree clip helpers in optim/clip are dtype-safe;
- the RDP accountant's epsilon is monotone in rounds and matches the
  closed-form Gaussian-mechanism optimum.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig, PrivacyConfig
from repro.core import metrics as M
from repro.core.rounds import run_federated
from repro.data import banking77, partition
from repro.kernels import ops, ref
from repro.optim import clip
from repro.privacy import dp
from repro.privacy.accountant import GaussianAccountant
from repro.privacy.secure_agg import SecureAggSession, flat_fixed_point

CFG = ModelConfig(name="priv-t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=192,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=64)

FRAMEWORKS = ("fedllm", "kd", "split")


@pytest.fixture(scope="module")
def small_case():
    pub = banking77.generate(24, CFG.vocab_size, 12, seed=0)
    tr = banking77.generate(96, CFG.vocab_size, 12, seed=1)
    te = banking77.generate(16, CFG.vocab_size, 12, seed=2)
    return pub, partition.iid_partition(tr, 3, seed=0), te


def _fed(**kw):
    base = dict(framework="fedllm", n_clients=3, rounds=1, lora_rank=4,
                lora_dropout=0.0, split_layer=1, kd_epochs=1, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _run(fed, case, **kw):
    pub, clients, te = case
    return run_federated(CFG, fed, pub, clients, te, batch_size=8,
                        eval_batch=8, **kw)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


# --------------------------------------------------------------------------- #
# Acceptance: secure-agg masking is bit-transparent at noise 0
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["sequential", "spmd"])
@pytest.mark.parametrize("framework", FRAMEWORKS)
def test_secure_agg_sync_bit_exact(small_case, framework, backend):
    """secure_agg=True (noise 0): histories and final params reproduce
    the non-private engine bit-for-bit; the ledger differs only by the
    secagg_* overhead events; mask cancellation is verified inside the
    session (uint64 arithmetic) on every aggregation."""
    fed = _fed(framework=framework, backend=backend)
    plain = _run(fed, small_case)
    sec = _run(dataclasses.replace(
        fed, privacy=PrivacyConfig(secure_agg=True)), small_case)
    for hp, hs in zip(plain.history, sec.history):
        assert hp.loss == hs.loss, framework
        assert hp.accuracy == hs.accuracy, framework
    assert _trees_equal(plain.final_lora, sec.final_lora), framework
    # ledger: identical modulo the privacy overhead
    strip = [(e.round, e.client, e.name, e.direction, e.bytes)
             for e in sec.ledger.payload_events()]
    full = [(e.round, e.client, e.name, e.direction, e.bytes)
            for e in plain.ledger.events]
    assert strip == full, framework
    assert sec.ledger.privacy_overhead_bytes() > 0, framework


@pytest.mark.parametrize("backend", ["sequential", "spmd"])
@pytest.mark.parametrize("framework", FRAMEWORKS)
def test_secure_agg_async_bit_exact(small_case, framework, backend):
    """Same acceptance under async aggregation: start cohorts deliver
    across rounds, so the dropout/recovery path (mask reconstruction
    for absent members) runs and still cancels exactly."""
    fed = _fed(framework=framework, backend=backend, rounds=3,
               aggregation="async", max_staleness=3)
    plain = _run(fed, small_case)
    sec = _run(dataclasses.replace(
        fed, privacy=PrivacyConfig(secure_agg=True)), small_case)
    for hp, hs in zip(plain.history, sec.history):
        assert hp.loss == hs.loss, framework
        assert hp.accuracy == hs.accuracy, framework
    assert _trees_equal(plain.final_lora, sec.final_lora), framework


def test_secure_agg_overhead_backend_parity(small_case):
    """Privacy-overhead bytes are identical across execution backends
    (sync and async) — the acceptance criterion's ledger clause."""
    for agg, rounds in (("sync", 1), ("async", 3)):
        for framework in FRAMEWORKS:
            fed = _fed(framework=framework, rounds=rounds,
                       aggregation=agg,
                       privacy=PrivacyConfig(secure_agg=True))
            seq = _run(fed, small_case)
            spmd = _run(dataclasses.replace(fed, backend="spmd"),
                        small_case)
            key = (framework, agg)
            assert seq.ledger.privacy_overhead_bytes() == \
                spmd.ledger.privacy_overhead_bytes(), key
            seq_pe = [(e.round, e.client, e.name, e.direction, e.bytes)
                      for e in seq.ledger.events
                      if e.name in M.PRIVACY_NAMES]
            spmd_pe = [(e.round, e.client, e.name, e.direction, e.bytes)
                       for e in spmd.ledger.events
                       if e.name in M.PRIVACY_NAMES]
            assert sorted(seq_pe) == sorted(spmd_pe), key


def test_secure_agg_async_exercises_recovery(small_case):
    """With real delays some cohort members are absent from the event
    that sums their peers, so recovery shares are actually charged."""
    fed = _fed(rounds=4, aggregation="async", max_staleness=3,
               privacy=PrivacyConfig(secure_agg=True))
    res = _run(fed, small_case)
    assert res.ledger.by_name().get("secagg_recovery", 0) > 0


def test_secure_agg_masks_cancel_in_uint64():
    """Unit-level: masked sums minus recovered residuals equal the
    plain fixed-point sums exactly, including under partial delivery."""
    fed = _fed(privacy=PrivacyConfig(secure_agg=True))
    sess = SecureAggSession(fed)
    ledger = M.CommLedger()
    rng = np.random.default_rng(0)
    payloads = [rng.normal(size=7).astype(np.float32) for _ in range(3)]
    sess.begin_cohort(ledger, 0, [0, 1, 2])
    for ci, p in enumerate(payloads):
        sess.collect(0, ci, p)
    # each masked upload differs from its plain encoding ...
    q0 = flat_fixed_point(payloads[0], fed.privacy.secure_agg_frac_bits)
    assert not np.array_equal(sess.masked(0, 0), q0)
    # ... but a partial delivery (dropout) still unmasks exactly
    sess.deliver(ledger, 1, [(0, 0), (0, 2)])      # client 1 absent
    assert ledger.by_name()["secagg_recovery"] == 2 * 32
    sess.deliver(ledger, 2, [(0, 1)])              # straggler lands later


# --------------------------------------------------------------------------- #
# DP: determinism, backend parity, identical noise
# --------------------------------------------------------------------------- #
DP = PrivacyConfig(dp_clip=1.0, dp_noise_multiplier=0.5)


@pytest.mark.parametrize("framework", FRAMEWORKS)
def test_dp_run_seed_deterministic(small_case, framework):
    fed = _fed(framework=framework, privacy=DP)
    a = _run(fed, small_case)
    b = _run(fed, small_case)
    assert [h.loss for h in a.history] == [h.loss for h in b.history]
    assert [h.epsilon for h in a.history] == [h.epsilon for h in b.history]
    assert _trees_equal(a.final_lora, b.final_lora)


@pytest.mark.parametrize("framework", FRAMEWORKS)
def test_dp_backend_parity(small_case, framework):
    """Sequential vs SPMD under DP: identical ledger bytes (including
    dp_meta), epsilon, and losses within fp32 tolerance — the noise is
    bit-identical via the per-client fold_in keys, so any residual
    difference is float reduction order only."""
    fed = _fed(framework=framework, privacy=DP)
    seq = _run(fed, small_case)
    spmd = _run(dataclasses.replace(fed, backend="spmd"), small_case)
    assert seq.ledger.per_client_round() == spmd.ledger.per_client_round()
    assert seq.ledger.by_name() == spmd.ledger.by_name()
    assert seq.ledger.by_name().get("dp_meta", 0) > 0
    for hs, hp in zip(seq.history, spmd.history):
        assert abs(hs.loss - hp.loss) <= 1e-3, framework
        assert hs.epsilon == hp.epsilon, framework


def test_dp_noise_is_identical_across_backends():
    """The exact noise both backends add: privatize_tree under vmapped
    per-client keys reproduces the sequential per-client calls bit-for-
    bit (the fold_in stream is backend-free)."""
    fed = _fed(privacy=DP)
    tree = {"a": jnp.ones((3, 4)), "b": jnp.zeros((2,))}
    keys = jnp.stack([dp.noise_key(fed, 0, ci) for ci in range(3)])
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (3,) + x.shape), tree)
    batched = jax.vmap(
        lambda t, k: dp.privatize_tree(t, k, fed.privacy.noise_std))(
            stacked, keys)
    for ci in range(3):
        one = dp.privatize_tree(tree, dp.noise_key(fed, 0, ci),
                                fed.privacy.noise_std)
        for a, b in zip(jax.tree.leaves(one),
                        jax.tree.leaves(jax.tree.map(lambda x: x[ci],
                                                     batched))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_noise_key_grid_matches_scalar_keys():
    """The vmapped (C, S) grid the SPMD split engines consume is
    bit-identical to the scalar per-(client, step) fold_in chain the
    sequential engines use."""
    fed = _fed(privacy=DP)
    grid = dp.noise_key_grid(fed, 3, [0, 2, 5], 4)
    for k, ci in enumerate([0, 2, 5]):
        for s in range(4):
            np.testing.assert_array_equal(
                np.asarray(grid[k, s]),
                np.asarray(dp.noise_key(fed, 3, ci, s)))
    # distinct (fed.seed, privacy.seed) pairs never collide
    a = dp.noise_key(_fed(seed=0, privacy=dataclasses.replace(
        DP, seed=9176)), 0, 0)
    b = dp.noise_key(_fed(seed=1, privacy=dataclasses.replace(
        DP, seed=0)), 0, 0)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_dp_changes_the_model_and_noise_zero_does_not():
    tree = {"a": jnp.ones((4, 4))}
    key = jax.random.PRNGKey(0)
    assert dp.privatize_tree(tree, key, 0.0) is tree
    noisy = dp.privatize_tree(tree, key, 0.1)
    assert not np.array_equal(np.asarray(noisy["a"]),
                              np.asarray(tree["a"]))


def test_noise_without_clip_rejected(small_case):
    fed = _fed(privacy=PrivacyConfig(dp_noise_multiplier=1.0))
    with pytest.raises(ValueError, match="dp_clip"):
        _run(fed, small_case)


def test_async_zero_staleness_equals_sync_with_privacy(small_case):
    """The privacy overlay preserves the async(max_staleness=0) == sync
    collapse exactly — cohorts, noise keys and dp_meta all line up."""
    priv = PrivacyConfig(dp_clip=1.0, dp_noise_multiplier=0.5,
                         secure_agg=True)
    fed = _fed(rounds=2, privacy=priv)
    sync = _run(fed, small_case)
    azync = _run(dataclasses.replace(fed, aggregation="async",
                                     max_staleness=0), small_case)
    assert sync.ledger.per_client_round() == azync.ledger.per_client_round()
    assert sync.ledger.by_name() == azync.ledger.by_name()
    for hs, ha in zip(sync.history, azync.history):
        assert hs.loss == ha.loss
        assert hs.epsilon == ha.epsilon


# --------------------------------------------------------------------------- #
# Clip kernel + stacked-tree clip helpers
# --------------------------------------------------------------------------- #
def test_clip_kernel_matches_reference():
    g = jnp.asarray(np.random.default_rng(0).normal(
        size=(8, 384)).astype(np.float32)) * 3.0
    want = ref.clip_mean_rows_ref(g, 1.0)
    with ops.policy_scope("pallas"):
        got = ops.clip_mean_rows(g, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
    # odd, prime-ish row width exercises the whole-dim block fallback
    g2 = jnp.asarray(np.random.default_rng(1).normal(
        size=(4, 257)).astype(np.float32))
    with ops.policy_scope("pallas"):
        got2 = ops.clip_mean_rows(g2, 0.5)
    np.testing.assert_allclose(np.asarray(got2),
                               np.asarray(ref.clip_mean_rows_ref(g2, 0.5)),
                               atol=1e-6)


def test_clipped_grad_mean_tree_roundtrip():
    """Flatten -> clip -> unflatten preserves structure/dtype and
    matches the optim/clip per-example reference composed with mean."""
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.normal(size=(6, 3, 5)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(6, 2)), jnp.bfloat16)}
    out = dp.clipped_grad_mean(tree, 0.7)
    assert out["w"].shape == (3, 5) and out["b"].shape == (2,)
    assert out["b"].dtype == jnp.bfloat16
    clipped, norms = clip.clip_per_example(tree, 0.7)
    want = jax.tree.map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0), clipped)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(want["w"]), atol=1e-6)
    assert bool((norms > 0).all())


def test_per_example_clip_helpers_dtype_safe():
    rng = np.random.default_rng(3)
    tree = {"x": jnp.asarray(rng.normal(size=(5, 7)) * 10, jnp.bfloat16)}
    norms = clip.per_example_global_norm(tree)
    assert norms.dtype == jnp.float32 and norms.shape == (5,)
    clipped, _ = clip.clip_per_example(tree, 1.0)
    assert clipped["x"].dtype == jnp.bfloat16
    post = clip.per_example_global_norm(clipped)
    assert bool((post <= 1.0 + 0.1).all())      # bf16 rounding slack
    # all-zero tree: the eps guard keeps the scale finite
    zeros = {"x": jnp.zeros((3, 4), jnp.bfloat16)}
    zc, zn = clip.clip_per_example(zeros, 1.0)
    assert bool(jnp.isfinite(jnp.asarray(zn)).all())
    assert bool((zc["x"] == 0).all())
    t, n = clip.clip_by_global_norm(zeros, 1.0)
    assert bool(jnp.isfinite(n)) and bool((t["x"] == 0).all())


def test_per_example_clip_actually_bounds_training_influence(small_case):
    """End-to-end: a clip-only DP run (no noise) differs from the plain
    run — the per-example clipping is really in the step."""
    fed = _fed()
    plain = _run(fed, small_case)
    clipped = _run(dataclasses.replace(
        fed, privacy=PrivacyConfig(dp_clip=1e-3)), small_case)
    assert not _trees_equal(plain.final_lora, clipped.final_lora)
    assert np.isfinite(clipped.history[-1].loss)


# --------------------------------------------------------------------------- #
# Accountant
# --------------------------------------------------------------------------- #
def test_accountant_monotone_in_rounds():
    acct = GaussianAccountant(noise_multiplier=1.0, delta=1e-5)
    eps = [acct.epsilon(t) for t in range(0, 40, 4)]
    assert eps[0] == 0.0
    assert all(b > a for a, b in zip(eps[1:], eps[2:]))


def test_accountant_matches_closed_form():
    for sigma in (0.5, 1.0, 2.0):
        for steps in (1, 10, 100):
            acct = GaussianAccountant(sigma, delta=1e-5)
            grid = acct.epsilon(steps)
            exact = acct.closed_form_epsilon(steps)
            # grid minimum approaches the analytic optimum from above
            assert grid >= exact - 1e-9, (sigma, steps)
            assert grid <= exact * 1.05 + 1e-6, (sigma, steps)


def test_accountant_edge_cases():
    acct = GaussianAccountant(0.0, delta=1e-5)
    assert math.isinf(acct.epsilon(1))
    with pytest.raises(ValueError, match="delta"):
        GaussianAccountant(1.0, delta=2.0)
    with pytest.raises(ValueError, match="sample_rate"):
        GaussianAccountant(1.0, sample_rate=0.0)
    with pytest.raises(ValueError, match="sample_rate"):
        GaussianAccountant(1.0, sample_rate=1.5)
    # a subsampled accountant needs integer orders >= 2 in the grid
    with pytest.raises(ValueError, match="integer order"):
        GaussianAccountant(1.0, orders=(1.5, 2.5), sample_rate=0.5)
    # ...but a fractional-only grid is fine at q = 1 (never consulted)
    assert GaussianAccountant(1.0, orders=(1.5, 2.5)).epsilon(1) > 0


# --------------------------------------------------------------------------- #
# Subsampling amplification (sampled Gaussian mechanism, MTZ'19 bound)
# --------------------------------------------------------------------------- #
def test_subsampled_rdp_matches_closed_form():
    """The log-space implementation equals a literal evaluation of the
    closed-form MTZ sum  1/(a-1) * log(sum_k C(a,k)(1-q)^(a-k) q^k
    exp((k^2-k)/(2 sigma^2)))  wherever the latter stays finite."""
    from repro.privacy.accountant import subsampled_gaussian_rdp

    for sigma in (0.8, 1.0, 2.0):
        for q in (0.01, 0.1, 0.25):
            for a in (2, 3, 5, 8, 16):
                direct = sum(
                    math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                    * math.exp((k * k - k) / (2.0 * sigma ** 2))
                    for k in range(a + 1))
                want = math.log(direct) / (a - 1)
                got = subsampled_gaussian_rdp(a, sigma, q)
                assert got == pytest.approx(want, rel=1e-12), (sigma, q, a)


def test_subsampled_rdp_reduces_to_gaussian_at_q1():
    from repro.privacy.accountant import (gaussian_rdp,
                                          subsampled_gaussian_rdp)

    for sigma in (0.5, 1.0, 2.0):
        for a in (2, 4, 32):
            assert subsampled_gaussian_rdp(a, sigma, 1.0) == \
                pytest.approx(gaussian_rdp(a, sigma))


def test_subsampled_epsilon_monotone_in_q_and_amplifies():
    """Less data seen per release -> smaller epsilon; the q=1 limit is
    the plain Gaussian composition."""
    full = GaussianAccountant(1.0, delta=1e-5)
    eps = [GaussianAccountant(1.0, delta=1e-5, sample_rate=q).epsilon(10)
           for q in (0.05, 0.2, 0.5, 1.0)]
    assert all(a < b for a, b in zip(eps, eps[1:]))
    assert eps[-1] == full.epsilon(10)
    assert eps[0] < full.epsilon(10) / 3


def test_epsilon_reported_per_round(small_case):
    """The engines report the subsampling rate q = batch / |local data|
    to the accountant (8 / 32 on this fixture), so the per-round epsilon
    matches a subsampled accountant at exactly that rate."""
    fed = _fed(rounds=2, privacy=DP)
    res = _run(fed, small_case)
    eps = [h.epsilon for h in res.history]
    assert eps[0] > 0 and eps[1] > eps[0]
    q = 8 / len(small_case[1][0]["tokens"])
    acct = GaussianAccountant(DP.dp_noise_multiplier, DP.dp_delta,
                              sample_rate=q)
    assert eps[0] == acct.epsilon(1) and eps[1] == acct.epsilon(2)
    # amplification: the subsampled figure beats the q=1 composition
    full = GaussianAccountant(DP.dp_noise_multiplier, DP.dp_delta)
    assert eps[0] < full.epsilon(1)
    # plain runs report 0 (no DP, no accounting, no claim)...
    assert all(h.epsilon == 0.0 for h in _run(_fed(), small_case).history)
    # ...while clip-without-noise reports inf (active, no guarantee)
    clip_only = _run(_fed(privacy=PrivacyConfig(dp_clip=1.0)), small_case)
    assert all(math.isinf(h.epsilon) for h in clip_only.history)
