"""Roofline machinery: HLO collective parser, hw math, model-FLOPs."""
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES
from repro.roofline import collectives, hw
from repro.roofline.analysis import model_flops_for

HLO_SNIPPET = """
ENTRY %main {
  %ag = bf16[16,128,1024]{2,1,0} all-gather(bf16[16,128,64] %x), dim=2
  %ar = f32[256,256]{1,0} all-reduce(f32[256,256] %y), to_apply=%sum
  %rs.5 = f32[16,16]{1,0} reduce-scatter(f32[256,16] %z), dim=0
  %a2a = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) all-to-all(bf16[8,64] %p, bf16[8,64] %q)
  %cp = u32[4]{0} collective-permute(u32[4] %r), pairs={{0,1}}
  %not_a_collective = f32[9999,9999]{1,0} dot(f32[2,2] %a, f32[2,2] %b)
}
"""


def test_collective_parser_kinds_and_bytes():
    cb = collectives.collective_bytes(HLO_SNIPPET)
    assert cb["all-gather"] == 16 * 128 * 1024 * 2
    assert cb["all-reduce"] == 256 * 256 * 4
    assert cb["reduce-scatter"] == 16 * 16 * 4
    assert cb["all-to-all"] == 2 * 8 * 64 * 2
    assert cb["collective-permute"] == 4 * 4
    assert "dot" not in cb
    total = collectives.total_collective_bytes(HLO_SNIPPET)
    assert total == sum(cb.values())


def test_hw_roofline_math():
    assert hw.compute_time_s(197e12, 1) == pytest.approx(1.0)
    assert hw.memory_time_s(819e9, 1) == pytest.approx(1.0)
    assert hw.collective_time_s(50e9, 1) == pytest.approx(1.0)
    assert hw.compute_time_s(197e12, 256) == pytest.approx(1 / 256)


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen3-1.7b")
    t = model_flops_for(cfg, SHAPES["train_4k"])
    p = model_flops_for(cfg, SHAPES["prefill_32k"])
    d = model_flops_for(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert t == pytest.approx(6 * n * 256 * 4096)
    assert p == pytest.approx(2 * n * 32 * 32768)
    assert d == pytest.approx(2 * n * 128)


def test_moe_active_flops_smaller():
    cfg = get_config("qwen3-moe-235b-a22b")
    t = model_flops_for(cfg, SHAPES["train_4k"])
    assert t < 6 * cfg.param_count() * 256 * 4096 / 5   # ~10x sparsity
