"""Model-substrate correctness: decode-vs-forward equivalence per family,
chunked WKV vs sequential oracle, RoPE/mask properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import common, rwkv6
from repro.models.factory import build_model

FAMS = {
    "dense": ModelConfig(name="dense", family="dense", n_layers=3,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=97),
    "swa": ModelConfig(name="swa", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                       sliding_window=4),
        # capacity factor 4.0: no token drops, so decode == forward exactly
    "moe": ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=97,
                       n_experts=4, top_k=2, moe_capacity_factor=4.0),
    "hybrid": ModelConfig(name="hyb", family="hybrid", n_layers=5,
                          d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
                          vocab_size=97, local_window=4, lru_width=64,
                          layer_pattern=("rglru", "rglru", "local_attn")),
    "ssm": ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=128, vocab_size=97,
                       layer_pattern=("rwkv6",), head_dim=16),
    "audio": ModelConfig(name="audio", family="audio", n_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                         vocab_size=97, activation="gelu", norm="layernorm",
                         use_rope=False, max_position_embeddings=128,
                         n_encoder_layers=2, encoder_seq_len=16),
}


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_decode_matches_forward(fam):
    cfg = FAMS[fam]
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    T = 9
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, T), 1,
                              cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (1, cfg.encoder_seq_len, cfg.d_model))
    full, _ = model.forward(p, batch)
    cache = model.init_cache(p, 1, 32, batch, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(p, cache, toks[:, t], jnp.asarray(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(full - dec)))
    assert err < 2e-3, (fam, err)


def test_scan_vs_unrolled_forward():
    cfg = FAMS["dense"]
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 97}
    a, _ = model.forward(p, batch, scan_layers=True)
    b, _ = model.forward(p, batch, scan_layers=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_remat_matches_plain():
    cfg = FAMS["dense"]
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 97}
    a, _ = model.forward(p, batch, remat="none")
    b, _ = model.forward(p, batch, remat="full")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_wkv_chunked_matches_sequential():
    B, S, H, D = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    logw = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H, D)))
    u = 0.1 * jax.random.normal(ks[4], (H, D))
    y1, s1 = rwkv6.wkv_ref(r, k, v, logw, u)
    y2, s2 = rwkv6.wkv_chunked(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_causal_mask_window():
    m = common.causal_mask(4, 4, window=2)
    expect = np.array([[1, 0, 0, 0], [1, 1, 0, 0], [0, 1, 1, 0],
                       [0, 0, 1, 1]], bool)
    np.testing.assert_array_equal(np.asarray(m), expect)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = common.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i-j: shift both positions
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(pi, pj):
        qi = common.apply_rope(q, jnp.asarray([[pi]]), 10000.0)
        kj = common.apply_rope(k, jnp.asarray([[pj]]), 10000.0)
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_moe_capacity_drop_keeps_output_finite():
    cfg = FAMS["moe"]
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((4, 8), jnp.int32)}   # worst-case routing
    logits, aux = model.forward(p, batch)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) >= 0.0
