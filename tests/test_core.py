"""Core federated-framework unit tests: KD knowledge processing, split
LoRA partitioning, metrics accounting, compression wire sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core import compression, kd, metrics, split, tasks
from repro.core.fedavg import make_fns
from repro.data import banking77, partition
from repro.models.factory import build_model
from repro.peft import lora as lora_lib

CFG = ModelConfig(name="t", family="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=128)


# --------------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------------- #
def test_ledger_accounting():
    led = metrics.CommLedger()
    led.record(0, 0, "lora_params", metrics.UP, 100)
    led.record(0, 1, "lora_params", metrics.UP, 200)
    led.record(1, 0, "logits", metrics.DOWN, 50)
    assert led.total() == 350
    assert led.total(metrics.UP) == 300
    assert led.per_round() == {0: 300, 1: 50}
    assert led.by_name() == {"lora_params": 300, "logits": 50}
    assert led.mean_client_bytes_per_round() == 350 / 3


def test_flops_orderings():
    """KD does strictly more client work than FedLLM; split strictly
    less (paper Table I row 3)."""
    n_tok, n_lora = 10_000, 1_000
    fed = metrics.train_flops(CFG, n_tok, True, n_lora)
    kd_extra = fed + metrics.fwd_flops(CFG, n_tok) + metrics.train_flops(
        CFG, n_tok, True, n_lora)
    split_ = metrics.train_flops(CFG, n_tok, True, n_lora, frac_layers=0.25)
    assert kd_extra > fed > split_


def test_logit_bytes_classification_vs_generative():
    """Paper SSIII.B: generative logits are ~V/77 x bigger."""
    n = 1000
    cls = metrics.logit_bytes(n, 77)
    gen = metrics.logit_bytes(n, 50_000)
    assert gen / cls == pytest.approx(50_000 / 77, rel=1e-6)
    topk = metrics.logit_bytes(n, 50_000, topk=32)
    assert topk < gen / 100
    q8 = metrics.logit_bytes(n, 50_000, quant_bits=8)
    assert q8 == n * (50_000 + 4)


# --------------------------------------------------------------------------- #
# KD knowledge processing
# --------------------------------------------------------------------------- #
def test_aggregate_knowledge_weighted_mean():
    a = np.ones((10, 5), np.float32)
    b = 3 * np.ones((10, 5), np.float32)
    agg = kd.aggregate_knowledge([a, b], weights=[1, 3])
    np.testing.assert_allclose(agg, 2.5)


def test_aggregate_knowledge_entropy_filter():
    rng = np.random.default_rng(0)
    confident = rng.normal(size=(20, 5)).astype(np.float32) * 10
    noisy = np.zeros((20, 5), np.float32)               # max entropy
    agg = kd.aggregate_knowledge([confident, noisy],
                                 entropy_filter_frac=0.5)
    # high-entropy samples replaced by the confident client's logits
    ent_mean = np.asarray(
        kd._entropy_jnp(jnp.stack([jnp.asarray(confident),
                                   jnp.asarray(noisy)]))).mean(0)
    worst = ent_mean >= np.quantile(ent_mean, 0.5)
    np.testing.assert_allclose(np.asarray(agg)[worst], confident[worst],
                               rtol=1e-5)


def test_align_public_dataset_shifts_distribution():
    pub = banking77.generate(2000, 512, 32, seed=0)
    hist = np.zeros(77)
    hist[:10] = 0.1                                     # clients only use 10
    aligned = kd.align_public_dataset(pub, [hist], 1000, seed=1)
    frac = (aligned["labels"] < 10).mean()
    assert frac > 0.9
    assert len(aligned["tokens"]) == 1000


def test_compress_for_wire_topk_smaller():
    fed_dense = FedConfig(logit_topk=0)
    fed_topk = FedConfig(logit_topk=8)
    logits = np.random.default_rng(0).normal(
        size=(50, 256)).astype(np.float32)
    _, wire_d = kd.compress_for_wire(logits, fed_dense)
    out, wire_t = kd.compress_for_wire(logits, fed_topk)
    assert wire_t < wire_d / 10
    np.testing.assert_array_equal(out.argmax(-1), logits.argmax(-1))


# --------------------------------------------------------------------------- #
# Split-FedLLM internals
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def split_setup():
    model = build_model(CFG)
    base = model.init(jax.random.PRNGKey(0))
    lt = lora_lib.init_lora(jax.random.PRNGKey(1), base,
                            ("wq", "wk", "wv"), 4)
    return model, base, lt


def test_split_join_lora_roundtrip(split_setup):
    model, base, lt = split_setup
    c, s = split.split_lora(lt, 2)
    joined = split.join_lora(c, s)
    for a, b in zip(jax.tree.leaves(lt), jax.tree.leaves(joined)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_split_step_runs_and_learns(split_setup):
    model, base, lt = split_setup
    fed = FedConfig(framework="split", split_layer=2, lora_rank=4,
                    lora_dropout=0.0, lr=5e-3)
    sfns = split.make_split_fns(model, fed, task="classification")
    L = sfns["n_client_groups"]
    c_lt, s_lt = split.split_lora(lt, L)
    base_c, base_s = split.split_base(base, L, False)
    c_opt, s_opt = sfns["opt_init"](c_lt), sfns["opt_init"](s_lt)
    data = banking77.generate(64, CFG.vocab_size, 24, seed=0)
    batch = {k: jnp.asarray(v[:16]) for k, v in data.items()}
    losses = []
    for i in range(8):
        c_lt, s_lt, c_opt, s_opt, loss = sfns["split_train_step"](
            base_c, base_s, c_lt, s_lt, c_opt, s_opt, batch,
            jax.random.PRNGKey(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_split_quantized_wire_smaller(split_setup):
    model, _, _ = split_setup
    fed32 = FedConfig(framework="split", split_layer=1)
    fed8 = FedConfig(framework="split", split_layer=1,
                     activation_quant_bits=8)
    s32 = split.make_split_fns(model, fed32)
    s8 = split.make_split_fns(model, fed8)
    up32, down32 = s32["wire_bytes_per_batch"]((16, 24))
    up8, down8 = s8["wire_bytes_per_batch"]((16, 24))
    assert up8 < up32 / 3 and down8 < down32 / 3


def test_choose_split_point_monotone():
    pts = [split.choose_split_point(CFG, b, 10_000)
           for b in (1e6, 1e9, 1e12, 1e15)]
    assert pts == sorted(pts)
    assert 1 <= min(pts) and max(pts) <= CFG.n_layers - 1


# --------------------------------------------------------------------------- #
# tasks
# --------------------------------------------------------------------------- #
def test_class_logits_gather_position():
    logits = jnp.arange(2 * 5 * 100, dtype=jnp.float32).reshape(2, 5, 100)
    batch = {"tokens": jnp.ones((2, 5), jnp.int32),
             "lengths": jnp.asarray([3, 5], jnp.int32)}
    cl = tasks.class_logits(logits, batch)
    np.testing.assert_allclose(np.asarray(cl[0]),
                               np.asarray(logits[0, 2, 1:78]))
    np.testing.assert_allclose(np.asarray(cl[1]),
                               np.asarray(logits[1, 4, 1:78]))
