"""Fault tolerance: seeded fault injection (faults/plan.py), the
upload-seam validation middleware and quorum gate
(core/round_program.py), Byzantine-robust aggregation
(core/fed_spmd.robust_client_combine) and bit-exact checkpoint/resume
(checkpoint/federated.py).

The acceptance properties pinned here:

- zero-fault robust-aggregation runs report the SAME ledger bytes as
  the plain engines (the robust statistic changes math, never wire
  sizes);
- a killed run resumed from its last checkpoint finishes bit-identical
  to an uninterrupted one — ledger events, metric history and final
  params — for all three frameworks (incl. async + secure-agg, whose
  in-flight payloads, schedule RNGs and mask vectors all checkpoint);
- with dropouts and Byzantine clients injected, every engine completes
  all rounds, quarantines the poisoned payloads, and the final model is
  finite;
- trimmed-mean aggregation holds accuracy near the clean run with a
  corrupt client in the cohort.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FaultConfig, FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.core import fed_spmd
from repro.core.rounds import run_federated
from repro.data import banking77, partition
from repro.faults.plan import FaultPlan

FRAMEWORKS = ("fedllm", "kd", "split")


@pytest.fixture(scope="module")
def case_study():
    cfg = gpt2_tiny()
    pub, tr, te = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                         scale=0.04)
    clients = partition.iid_partition(tr, 3)
    return cfg, pub, clients, te


def _fed(fw, **kw):
    base = dict(framework=fw, n_clients=3, rounds=2, lora_rank=4,
                lora_dropout=0.0, split_layer=2, kd_epochs=1, seed=0)
    base.update(kw)
    return FedConfig(**base)


def _run(case, fed, **kw):
    cfg, pub, clients, te = case
    return run_federated(cfg, fed, pub, clients, te, batch_size=16,
                         eval_batch=64, **kw)


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(tree))


# --------------------------------------------------------------------------- #
# FaultPlan: seeded, deterministic, mode-correct
# --------------------------------------------------------------------------- #
def test_fault_plan_deterministic():
    fed = _fed("fedllm", faults=FaultConfig(dropout_rate=0.3,
                                            straggler_rate=0.3,
                                            byzantine=1))
    a, b = FaultPlan(fed, 3), FaultPlan(fed, 3)
    for rnd in range(5):
        for ci in range(3):
            assert a.dropped(rnd, ci) == b.dropped(rnd, ci)
            assert a.extra_delay(rnd, ci) == b.extra_delay(rnd, ci)
    assert a.byzantine == b.byzantine
    assert len(a.byzantine) == 1


def test_fault_plan_seed_moves_faults():
    fed = _fed("fedllm", faults=FaultConfig(dropout_rate=0.5, seed=0))
    other = _fed("fedllm", faults=FaultConfig(dropout_rate=0.5, seed=1))
    grid = lambda p: [p.dropped(r, c) for r in range(8) for c in range(3)]
    assert grid(FaultPlan(fed, 3)) != grid(FaultPlan(other, 3))


def test_fault_plan_corruption_modes():
    x = {"w": jnp.ones((2, 3), jnp.float32),
         "i": jnp.arange(3)}               # int leaf must pass through
    for mode, check in [
            ("nan", lambda y: np.isnan(y).all()),
            ("inf", lambda y: np.isinf(y).all()),
            ("sign_flip", lambda y: np.array_equal(y, -np.ones((2, 3)))),
            ("norm_inflation",
             lambda y: np.allclose(y, 100.0 * np.ones((2, 3))))]:
        fed = _fed("fedllm", faults=FaultConfig(byzantine=1,
                                                byzantine_mode=mode))
        plan = FaultPlan(fed, 3)
        (bad_ci,) = plan.byzantine
        out = plan.corrupt(x, 0, bad_ci)
        assert check(np.asarray(out["w"])), mode
        np.testing.assert_array_equal(np.asarray(out["i"]),
                                      np.arange(3), err_msg=mode)
        # non-byzantine clients are untouched
        ok_ci = next(c for c in range(3) if c not in plan.byzantine)
        _assert_trees_equal(plan.corrupt(x, 0, ok_ci), x, mode)


# --------------------------------------------------------------------------- #
# robust_client_combine: numpy reference + degenerate cohorts
# --------------------------------------------------------------------------- #
def test_robust_combine_matches_numpy_reference():
    rng = np.random.default_rng(0)
    stack = {"a": jnp.asarray(rng.normal(size=(5, 3, 2)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)}
    w = jnp.asarray(rng.uniform(0.5, 2.0, 5), jnp.float32)

    med = fed_spmd.robust_client_combine(stack, w, "median")
    np.testing.assert_allclose(np.asarray(med["a"]),
                               np.median(np.asarray(stack["a"]), axis=0),
                               rtol=1e-6)

    tm = fed_spmd.robust_client_combine(stack, w, "trimmed_mean",
                                        trim_frac=0.2)
    ref = np.sort(np.asarray(stack["b"]), axis=0)[1:-1].mean(axis=0)
    np.testing.assert_allclose(np.asarray(tm["b"]), ref, rtol=1e-5)

    # norm_clip with a huge threshold degrades to the weighted mean
    nc = fed_spmd.robust_client_combine(stack, w, "norm_clip",
                                        clip_norm=1e9)
    plain = fed_spmd.weighted_client_mean(stack, w)
    np.testing.assert_allclose(np.asarray(nc["a"]), np.asarray(plain["a"]),
                               rtol=1e-5, atol=1e-6)

    with pytest.raises(ValueError):
        fed_spmd.robust_client_combine(stack, w, "mode")


def test_robust_combine_rejects_outlier():
    good = np.ones((4, 8), np.float32)
    stack = {"a": jnp.asarray(np.concatenate([good, 1e6 * good[:1]]))}
    w = jnp.ones(5, jnp.float32)
    for method, kw in [("median", {}),
                       ("trimmed_mean", {"trim_frac": 0.25}),
                       ("norm_clip", {})]:
        out = fed_spmd.robust_client_combine(stack, w, method, **kw)
        assert np.abs(np.asarray(out["a"])).max() < 100.0, method


def test_zero_weight_guards():
    from repro.core.fedavg import fedavg
    from repro.core.kd import aggregate_knowledge

    stack = {"a": jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)}
    zero = jnp.zeros(2, jnp.float32)
    out = fed_spmd.weighted_client_mean(stack, zero)
    np.testing.assert_allclose(np.asarray(out["a"]), [2.0, 3.0])

    trees = [{"a": jnp.ones(2, jnp.float32)},
             {"a": 3.0 * jnp.ones(2, jnp.float32)}]
    out = fedavg(trees, [0.0, 0.0])
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0 * np.ones(2))

    logits = [jnp.ones((3, 4), jnp.float32), 3.0 * jnp.ones((3, 4))]
    agg = aggregate_knowledge(logits, [0.0, 0.0])
    np.testing.assert_allclose(np.asarray(agg), 2.0 * np.ones((3, 4)))


# --------------------------------------------------------------------------- #
# Zero-fault robust aggregation: ledger parity with the plain engines
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fw", FRAMEWORKS)
def test_robust_agg_ledger_parity_zero_faults(case_study, fw):
    plain = _run(case_study, _fed(fw))
    robust = _run(case_study, _fed(fw, robust_agg="trimmed_mean"))
    assert plain.ledger.per_round() == robust.ledger.per_round(), fw
    assert plain.ledger.by_name() == robust.ledger.by_name(), fw
    assert plain.ledger.per_client_round() == \
        robust.ledger.per_client_round(), fw
    for hp, hr in zip(plain.history, robust.history):
        assert hp.comm_bytes_per_client == hr.comm_bytes_per_client, fw
    assert robust.rollovers == 0


# --------------------------------------------------------------------------- #
# Kill-and-resume: bit-exact crash recovery for all three frameworks
# --------------------------------------------------------------------------- #
RESUME_CASES = [
    # fedllm takes the hardest combo: async arrivals (in-flight payloads
    # + participation RNGs) under secure aggregation (mask vectors)
    ("fedllm", dict(aggregation="async", max_staleness=2)),
    ("kd", {}),
    ("split", {}),
]


@pytest.mark.parametrize("fw,extra", RESUME_CASES,
                         ids=[c[0] for c in RESUME_CASES])
def test_kill_and_resume_bit_exact(case_study, tmp_path, fw, extra):
    from repro.configs.base import PrivacyConfig

    kw = dict(extra)
    if fw == "fedllm":
        kw["privacy"] = PrivacyConfig(secure_agg=True)
    fed = _fed(fw, rounds=3, **kw)
    full = _run(case_study, fed)

    ckpt = str(tmp_path / f"ckpt_{fw}")
    # "crash" after round 2 of 3: run the truncated schedule with
    # checkpointing on, then resume the full schedule from disk
    _run(case_study, dataclasses.replace(fed, rounds=2),
         checkpoint_every=1, checkpoint_dir=ckpt)
    resumed = _run(case_study, fed, resume_from=ckpt)

    assert full.ledger.events == resumed.ledger.events, fw
    assert full.history == resumed.history, fw
    assert full.rollovers == resumed.rollovers, fw
    _assert_trees_equal(full.final_lora, resumed.final_lora, fw)


# --------------------------------------------------------------------------- #
# Faulted rounds complete; Byzantine tolerance; quorum rollover
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fw", FRAMEWORKS)
def test_faulted_run_completes_with_quarantine(case_study, fw):
    fed = _fed(fw, rounds=3, robust_agg="trimmed_mean", trim_frac=0.34,
               faults=FaultConfig(dropout_rate=0.3, byzantine=1,
                                  byzantine_mode="nan"))
    res = _run(case_study, fed)
    assert len(res.history) == 3, fw
    names = res.ledger.by_name()
    assert "quarantine" in names, (fw, sorted(names))
    assert res.ledger.fault_overhead_bytes() > 0, fw
    assert _finite(res.final_lora), fw


@pytest.mark.parametrize("fw", FRAMEWORKS)
def test_byzantine_tolerance_trimmed_mean(case_study, fw):
    """With one norm-inflating client in a 3-client cohort, trimmed-mean
    (trimming 1 from each side) must hold accuracy near the clean run —
    the f=1 Byzantine-tolerance claim."""
    clean = _run(case_study, _fed(fw))
    attacked = _run(case_study, _fed(
        fw, robust_agg="trimmed_mean", trim_frac=0.34,
        faults=FaultConfig(byzantine=1,
                           byzantine_mode="norm_inflation",
                           byzantine_scale=100.0)))
    assert _finite(attacked.final_lora), fw
    assert abs(clean.final_accuracy - attacked.final_accuracy) <= 0.2, \
        (fw, clean.final_accuracy, attacked.final_accuracy)


def test_quorum_rollover_deterministic(case_study):
    fed = _fed("fedllm", rounds=3, quorum=1.0,
               faults=FaultConfig(dropout_rate=0.5))
    a = _run(case_study, fed)
    b = _run(case_study, fed)
    assert a.rollovers > 0
    assert a.rollovers == b.rollovers
    assert len(a.history) == 3          # rolled rounds still complete
    assert a.ledger.events == b.ledger.events


def test_norm_screen_quarantines_inflated_payload(case_study):
    fed = _fed("fedllm", rounds=2, screen_factor=5.0,
               faults=FaultConfig(byzantine=1,
                                  byzantine_mode="norm_inflation",
                                  byzantine_scale=1000.0))
    res = _run(case_study, fed)
    assert "quarantine" in res.ledger.by_name()
    assert _finite(res.final_lora)


# --------------------------------------------------------------------------- #
# Nightly fault-injection matrix (CI's fault-matrix job selects cells
# via ``-k "<framework> and <backend>"``)
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("backend", ("sequential", "spmd", "cohort"))
@pytest.mark.parametrize("fw", FRAMEWORKS)
def test_fault_matrix(case_study, fw, backend):
    cfg, pub, _, te = case_study
    _, tr, _ = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                      scale=0.04)
    n = 4 if backend == "cohort" else 3
    clients = partition.iid_partition(tr, n)
    fed = _fed(fw, n_clients=n, rounds=2, backend=backend,
               cohort_size=2 if backend == "cohort" else 0,
               robust_agg="trimmed_mean", trim_frac=0.34,
               screen_factor=10.0,
               faults=FaultConfig(dropout_rate=0.25, byzantine=1,
                                  byzantine_mode="inf"))
    res = run_federated(cfg, fed, pub, clients, te, batch_size=16,
                        eval_batch=64)
    assert len(res.history) == 2, (fw, backend)
    assert "quarantine" in res.ledger.by_name(), (fw, backend)
    assert _finite(res.final_lora), (fw, backend)
