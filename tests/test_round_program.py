"""Unit tests for the composable round pipeline (core/round_program.py):
schedule semantics, subsampling-rate reporting, and the mesh-sharded
SPMD executor path (client-axis NamedShardings from launch/sharding)."""
import jax
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core import round_program as rp
from repro.core.rounds import run_federated
from repro.data import banking77, partition

CFG = ModelConfig(name="rp-t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=192,
                  qkv_bias=True, activation="gelu", norm="layernorm",
                  use_rope=False, max_position_embeddings=64)


@pytest.fixture(scope="module")
def small_case():
    pub = banking77.generate(24, CFG.vocab_size, 12, seed=0)
    tr = banking77.generate(96, CFG.vocab_size, 12, seed=1)
    te = banking77.generate(16, CFG.vocab_size, 12, seed=2)
    return pub, partition.iid_partition(tr, 3, seed=0), te


def _fed(**kw):
    base = dict(framework="fedllm", n_clients=3, rounds=1, lora_rank=4,
                lora_dropout=0.0, split_layer=1, kd_epochs=1, seed=0)
    base.update(kw)
    return FedConfig(**base)


# --------------------------------------------------------------------------- #
# Schedules
# --------------------------------------------------------------------------- #
def test_sync_schedule_everyone_starts_and_arrives_same_round():
    s = rp.SyncSchedule(_fed(), 3)
    assert s.starters(0) == [0, 1, 2]
    for ci in (2, 0, 1):
        s.submit(0, ci, f"p{ci}")
    jobs = s.pop_arrivals(0)
    assert [j.client for j in jobs] == [0, 1, 2]       # visit order
    assert all(j.start == j.arrival == 0 for j in jobs)
    assert s.pop_arrivals(1) == []


def test_async_schedule_in_flight_clients_do_not_restart():
    fed = _fed(aggregation="async", max_staleness=4, seed=1)
    s = rp.AsyncSchedule(fed, 4)
    assert s.starters(0) == [0, 1, 2, 3]
    for ci in s.starters(0):
        s.submit(0, ci, None)
    arrived = {j.client for j in s.pop_arrivals(0)}
    # whoever is still in flight cannot start round 1
    assert set(s.starters(1)) == arrived
    # zero max_staleness degenerates to the sync schedule
    s0 = rp.AsyncSchedule(_fed(aggregation="async", max_staleness=0), 3)
    for ci in s0.starters(0):
        s0.submit(0, ci, None)
    assert [j.client for j in s0.pop_arrivals(0)] == [0, 1, 2]


def test_make_schedule_dispatch():
    assert isinstance(rp.make_schedule(_fed(), 3), rp.SyncSchedule)
    assert isinstance(rp.make_schedule(_fed(aggregation="async"), 3),
                      rp.AsyncSchedule)


# --------------------------------------------------------------------------- #
# Subsampling-rate reporting (accountant wiring)
# --------------------------------------------------------------------------- #
def test_sample_rate_worst_case_over_clients():
    clients = [{"tokens": np.zeros((32, 4))}, {"tokens": np.zeros((8, 4))}]
    assert rp.sample_rate(clients, 8) == 1.0        # 8/8 clamps at 1
    clients = [{"tokens": np.zeros((32, 4))}, {"tokens": np.zeros((16, 4))}]
    assert rp.sample_rate(clients, 8) == 0.5        # max(8/32, 8/16)


def test_make_accountant_threads_sample_rate():
    from repro.configs.base import PrivacyConfig
    fed = _fed(privacy=PrivacyConfig(dp_clip=1.0, dp_noise_multiplier=1.0))
    a = rp.make_accountant(fed, sample_rate=0.25)
    assert a.sample_rate == 0.25
    assert rp.make_accountant(_fed()) is None       # DP off -> no claim


# --------------------------------------------------------------------------- #
# Stage-spec sourcing: the launch layer compiles the SAME specs
# --------------------------------------------------------------------------- #
def test_launch_builds_from_stage_specs():
    import inspect

    from repro.launch import steps
    src = inspect.getsource(steps)
    for sym in ("FedLLMProgram.spmd_round", "KDProgram.spmd_round",
                "SplitProgram.spmd_round"):
        assert f"round_program.{sym}" in src, sym


# --------------------------------------------------------------------------- #
# Mesh-sharded SPMD executor (client axis on the mesh)
# --------------------------------------------------------------------------- #
def _one_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_client_sharding_helpers():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import client_axes, client_axis_size
    from repro.launch.sharding import client_spec, shard_client_tree

    mesh = _one_device_mesh()
    assert client_axes(mesh) == ("data",)
    assert client_axis_size(mesh) == 1
    assert client_spec(mesh, 3) == P(("data",), None, None)
    tree = {"a": jax.numpy.ones((2, 3)), "b": jax.numpy.zeros((2,))}
    out = shard_client_tree(mesh, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))
        assert out[k].sharding.spec == client_spec(mesh, tree[k].ndim)


@pytest.mark.parametrize("framework", ["fedllm", "kd"])
def test_spmd_runtime_with_mesh_matches_unsharded(small_case, framework):
    """run_federated(..., mesh=...) drives the SPMD executor through
    explicit client-axis NamedShardings and reproduces the unsharded
    run: the mesh is a placement concern, never a numerics one."""
    pub, clients, te = small_case
    fed = _fed(framework=framework, backend="spmd")
    plain = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                          eval_batch=8)
    sharded = run_federated(CFG, fed, pub, clients, te, batch_size=8,
                            eval_batch=8, mesh=_one_device_mesh())
    assert plain.ledger.per_client_round() == \
        sharded.ledger.per_client_round()
    assert plain.ledger.by_name() == sharded.ledger.by_name()
    for hp, hs in zip(plain.history, sharded.history):
        assert abs(hp.loss - hs.loss) <= 1e-5, framework
    for a, b in zip(jax.tree.leaves(plain.final_lora),
                    jax.tree.leaves(sharded.final_lora)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)


def test_adapters_contain_no_per_driver_threading():
    """The acceptance clause: core/rounds*.py are adapters only — no
    privacy/hetero/async code paths left behind."""
    import inspect

    from repro.core import rounds, rounds_spmd

    for mod in (rounds, rounds_spmd):
        src = inspect.getsource(mod)
        for banned in ("privatize", "SecureAggSession", "secagg.",
                       "stale_weighted_avg", "rank_buckets",
                       "rank_segments", "harmonize_buckets",
                       "ParticipationSchedule"):
            assert banned not in src, (mod.__name__, banned)
