"""End-to-end behaviour tests: full federated rounds for all three paper
frameworks on the (reduced) case-study setup, asserting the paper's
qualitative claims (SSIII Table I) from the framework's own measurements."""
import jax
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.gpt2_small import gpt2_tiny
from repro.data import banking77, partition
from repro.core.rounds import run_federated


@pytest.fixture(scope="module")
def case_study():
    cfg = gpt2_tiny()
    pub, tr, te = banking77.paper_splits(cfg.vocab_size, pad_len=24,
                                         scale=0.04)
    clients = partition.iid_partition(tr, 3)
    return cfg, pub, clients, te


def _run(cfg, pub, clients, te, fw, rounds=2, **kw):
    base = dict(framework=fw, n_clients=3, rounds=rounds, lora_rank=4,
                lora_dropout=0.0, split_layer=2, kd_epochs=1, seed=0)
    base.update(kw)
    fed = FedConfig(**base)
    return run_federated(cfg, fed, pub, clients, te, batch_size=16,
                         eval_batch=64)


@pytest.fixture(scope="module")
def results(case_study):
    cfg, pub, clients, te = case_study
    return {fw: _run(cfg, pub, clients, te, fw)
            for fw in ("fedllm", "kd", "split")}


def test_all_frameworks_produce_finite_history(results):
    for fw, res in results.items():
        assert len(res.history) == 2
        for h in res.history:
            assert np.isfinite(h.loss), fw
            assert 0.0 <= h.accuracy <= 1.0, fw


def test_paper_table1_comm_ordering(results):
    """Split-FedLLMs incur the highest communication (paper SSIII.B/Fig 4:
    activations+grads scale with dataset x seq x d_model)."""
    comm = {fw: r.ledger.mean_client_bytes_per_round()
            for fw, r in results.items()}
    assert comm["split"] > comm["fedllm"]
    assert comm["split"] > comm["kd"]


def test_paper_table1_compute_ordering(results):
    """KD-FedLLMs have the highest client compute (FT + logit gen +
    client KD); Split the lowest (partial model)."""
    flops = {fw: np.mean(r.client_flops) for fw, r in results.items()}
    assert flops["kd"] > flops["fedllm"] > flops["split"]


def test_fedllm_learns(case_study):
    cfg, pub, clients, te = case_study
    res = _run(cfg, pub, clients, te, "fedllm", rounds=4)
    losses = [h.loss for h in res.history]
    assert losses[-1] < losses[0]


def test_kd_no_parameter_exchange(results):
    names = set(results["kd"].ledger.by_name())
    assert "lora_params" not in names
    assert "logits" in names


def test_split_wire_names(results):
    names = set(results["split"].ledger.by_name())
    assert {"activations", "act_grads", "lora_params"} <= names


def test_hetero_ranks_run(case_study):
    cfg, pub, clients, te = case_study
    res = _run(cfg, pub, clients, te, "fedllm", rounds=1,
               client_ranks=(2, 4, 8), lora_rank=8, hetero_agg="zeropad")
    assert np.isfinite(res.history[-1].loss)


def test_kd_with_topk_compression(case_study):
    cfg, pub, clients, te = case_study
    res_dense = _run(cfg, pub, clients, te, "kd", rounds=1)
    res_topk = _run(cfg, pub, clients, te, "kd", rounds=1, logit_topk=8)
    dense_b = res_dense.ledger.by_name()["logits"]
    topk_b = res_topk.ledger.by_name()["logits"]
    assert topk_b < dense_b      # SSIV.B.2: top-k shrinks the wire
