"""Dense MLP blocks: SwiGLU (llama/mistral/qwen), GELU (gpt2/whisper),
squared-ReLU (nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import hint, mm


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0, dtype=jnp.float32):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "w_gate": common.dense_init(ks[0], (d, ff), dtype),
            "w_in": common.dense_init(ks[1], (d, ff), dtype),
            "w_out": common.dense_init(ks[2], (ff, d), dtype,
                                       scale=ff ** -0.5),
        }
    return {
        "w_in": common.dense_init(ks[0], (d, ff), dtype),
        "w_out": common.dense_init(ks[1], (ff, d), dtype, scale=ff ** -0.5),
    }


def mlp_fwd(params, cfg: ModelConfig, x):
    if cfg.activation == "swiglu":
        g = common.silu(mm(x, params["w_gate"]))
        h = mm(x, params["w_in"]) * g
    else:
        act = common.relu2 if cfg.activation == "relu2" else common.gelu
        h = act(mm(x, params["w_in"]))
    h = hint(h, ("pod", "data"), None, "model")
    return mm(h, params["w_out"])
