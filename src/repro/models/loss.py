"""Loss functions: next-token cross-entropy (vocab-chunked), sequence
classification head loss (Banking77 case study), KD distillation loss
wrapper (delegates to kernels/kd_loss ops for the TPU path)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None, vocab_chunk: int = 0):
    """logits: (..., V) fp; labels: (...) int32; mask (...) or None.

    Returns (mean_loss, n_tokens).  fp32 accumulation; ``vocab_chunk`` is a
    hook for chunked LSE on very large vocabs (0 = dense).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll), jnp.asarray(nll.size, jnp.float32)
    mask = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / n, n


def next_token_loss(logits, tokens, mask=None):
    """Shifted LM loss.  logits: (B,S,V); tokens: (B,S)."""
    lg = logits[:, :-1]
    lb = tokens[:, 1:]
    m = None if mask is None else mask[:, 1:]
    return cross_entropy(lg, lb, m)


def kd_kl(student_logits, teacher_logits, temperature: float = 1.0,
          mask=None):
    """KL(teacher || student) with temperature, mean over tokens.

    Both logits (..., V).  The (soft) distillation loss of KD-FedLLMs
    (paper SS II.B); under kernel policy ``pallas`` this dispatches to
    the streaming vocab-chunked Pallas kernel (differentiable w.r.t.
    both logit sets via its custom_vjp backward).
    """
    from repro.kernels import ops as kernel_ops
    if kernel_ops.use_pallas():
        return kernel_ops.kd_loss(teacher_logits, student_logits,
                                  temperature=float(temperature), mask=mask)
    t = jnp.asarray(temperature, jnp.float32)
    ts = teacher_logits.astype(jnp.float32) / t
    ss = student_logits.astype(jnp.float32) / t
    tp = jax.nn.log_softmax(ts, axis=-1)
    sp = jax.nn.log_softmax(ss, axis=-1)
    kl = jnp.sum(jnp.exp(tp) * (tp - sp), axis=-1) * (t * t)
    if mask is None:
        return jnp.mean(kl)
    mask = mask.astype(jnp.float32)
    return jnp.sum(kl * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def classification_loss(logits_last, labels):
    """Intent-classification loss on the last-position logits restricted to
    the first ``n_classes`` vocab entries (Banking77 case study)."""
    return cross_entropy(logits_last, labels)[0]


def accuracy(logits, labels) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
