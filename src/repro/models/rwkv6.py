"""RWKV-6 "Finch" block [arXiv:2404.05892]: time-mix (WKV with
data-dependent decay) + channel-mix.

Per head (dk = dv = head_dim), with data-dependent per-channel decay w_t:

    S_t = diag(w_t) . S_{t-1} + k_t^T v_t          state: (dk, dv)
    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)

Full-sequence forward uses the chunkwise-parallel linear-attention
algorithm (intra-chunk quadratic + inter-chunk state carry): memory
O(T*d + T^2/Nc) instead of O(T*dk*dv), and the MXU-friendly TPU form.
Decode carries (B, H, dk, dv) state.  Token shift uses static learned
lerp (RWKV-5 style) for r/k/v/g; the decay w_t is data-dependent through
a rank-64 LoRA as in Finch — the headline Finch feature.

kernels/rwkv6_scan.py is the fused Pallas TPU path; ref oracle is the
step-by-step ``lax.scan`` here (``wkv_ref``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import mm

CHUNK = 16
DECAY_LORA = 64
_EXP_CLAMP = 80.0


def init_rwkv6(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.head_dim if cfg.head_dim else 64
    ks = jax.random.split(key, 12)
    p = {
        # time-mix
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": common.dense_init(ks[0], (d, d), dtype),
        "w_k": common.dense_init(ks[1], (d, d), dtype),
        "w_v": common.dense_init(ks[2], (d, d), dtype),
        "w_g": common.dense_init(ks[3], (d, d), dtype),
        "w_o": common.dense_init(ks[4], (d, d), dtype, scale=d ** -0.5),
        # data-dependent decay: w0 + tanh(x@A)@B
        "decay_w0": jnp.full((d,), -4.0, dtype),     # w ~ exp(-exp(-4)) ~ .98
        "decay_a": common.dense_init(ks[5], (d, DECAY_LORA), dtype),
        "decay_b": common.dense_init(ks[6], (DECAY_LORA, d), dtype,
                                     scale=DECAY_LORA ** -1.0),
        "bonus_u": jnp.zeros((d,), dtype),
        "ln_x": common.init_layernorm(d, dtype),     # group-norm surrogate
        # channel-mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_w_k": common.dense_init(ks[7], (d, cfg.d_ff), dtype),
        "cm_w_v": common.dense_init(ks[8], (cfg.d_ff, d), dtype,
                                    scale=cfg.d_ff ** -0.5),
        "cm_w_r": common.dense_init(ks[9], (d, d), dtype),
    }
    return p


def _shift(x, last=None):
    """x_{t-1} stream.  x: (B,S,d); ``last``: (B,d) from previous call."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _lerp(x, xp, mu):
    return x + (xp - x) * mu.astype(x.dtype)


def _decay(params, xw):
    """log(w_t) <= 0;  w_t = exp(-exp(w0 + tanh(x@A)@B))."""
    dd = jnp.tanh(mm(xw, params["decay_a"]))
    ww = params["decay_w0"].astype(jnp.float32) + mm(
        dd, params["decay_b"]).astype(jnp.float32)
    return -jnp.exp(jnp.clip(ww, -8.0, 3.0))        # log-decay, (B,S,d)


# --------------------------------------------------------------------------- #
# WKV core: reference scan and chunkwise-parallel form
# --------------------------------------------------------------------------- #
def wkv_ref(r, k, v, logw, u):
    """Step-by-step oracle.  r,k,v,logw: (B,S,H,D); u: (H,D).
    Returns y: (B,S,H,D), final state (B,H,D,D)."""
    B, S, H, D = r.shape
    f32 = jnp.float32

    def step(S_, inp):
        r_, k_, v_, lw_ = inp                        # (B,H,D)
        kv = k_[..., :, None] * v_[..., None, :]     # (B,H,D,D)
        y = jnp.einsum("bhd,bhde->bhe", r_, S_ + u[None, :, :, None] * kv)
        S_ = jnp.exp(lw_)[..., None] * S_ + kv
        return S_, y

    S0 = jnp.zeros((B, H, D, D), f32)
    xs = tuple(jnp.moveaxis(a.astype(f32), 1, 0) for a in (r, k, v, logw))
    Sf, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), Sf


def wkv_chunked(r, k, v, logw, u, state=None, chunk: int = CHUNK):
    """Chunkwise-parallel WKV.  Shapes as wkv_ref; ``state``: (B,H,D,D)."""
    B, S, H, D = r.shape
    f32 = jnp.float32
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    N = S // chunk
    rs, ks, vs, lws = (
        a.astype(f32).reshape(B, N, chunk, H, D) for a in (r, k, v, logw))
    S0 = state if state is not None else jnp.zeros((B, H, D, D), f32)

    idx = jnp.arange(chunk)
    tri = idx[:, None] > idx[None, :]                # strict lower (j < i)

    def chunk_step(S_, inp):
        rc, kc, vc, lwc = inp                        # (B,chunk,H,D)
        la = jnp.cumsum(lwc, axis=1)                 # inclusive cum log-decay
        la_excl = la - lwc                           # exclusive (prod j<i)
        # inter-chunk: y_i += (r_i * exp(la_excl_i)) @ S
        r_sc = rc * jnp.exp(jnp.clip(la_excl, -_EXP_CLAMP, _EXP_CLAMP))
        y = jnp.einsum("bchd,bhde->bche", r_sc, S_)
        # intra-chunk: att[i,j] = sum_d r_i exp(la_excl_i - la_j) k_j, j<i
        k_sc = kc * jnp.exp(jnp.clip(-la, -_EXP_CLAMP, _EXP_CLAMP))
        att = jnp.einsum("bihd,bjhd->bhij", r_sc, k_sc)
        att = att * tri[None, None]
        diag = jnp.einsum("bihd,bihd->bhi", rc * u[None, None], kc)
        y = y + jnp.einsum("bhij,bjhd->bihd", att, vc) \
              + diag.transpose(0, 2, 1)[..., None] * vc
        # state update: S' = diag(exp(la_L)) S + sum_j exp(la_L - la_j) k_j v_j
        laL = la[:, -1]                              # (B,H,D)
        k_tail = kc * jnp.exp(jnp.clip(laL[:, None] - la, -_EXP_CLAMP,
                                       _EXP_CLAMP))
        S_ = jnp.exp(jnp.clip(laL, -_EXP_CLAMP, 0.0))[..., None] * S_ \
            + jnp.einsum("bchd,bche->bhde", k_tail, vc)
        return S_, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, lws))
    Sf, ys = jax.lax.scan(chunk_step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, D)
    return y, Sf


# --------------------------------------------------------------------------- #
# Block forward
# --------------------------------------------------------------------------- #
def _project(params, cfg, x, x_prev):
    d = cfg.d_model
    hd = cfg.head_dim if cfg.head_dim else 64
    H = d // hd
    B, S, _ = x.shape
    r = mm(_lerp(x, x_prev, params["mu_r"]), params["w_r"])
    k = mm(_lerp(x, x_prev, params["mu_k"]), params["w_k"])
    v = mm(_lerp(x, x_prev, params["mu_v"]), params["w_v"])
    g = jax.nn.silu(mm(_lerp(x, x_prev, params["mu_g"]), params["w_g"]))
    logw = _decay(params, _lerp(x, x_prev, params["mu_w"]))
    shp = (B, S, H, hd)
    u = params["bonus_u"].astype(jnp.float32).reshape(H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            logw.reshape(shp), u, g)


def timemix_fwd(params, cfg: ModelConfig, x, state=None, x_last=None):
    """x: (B,S,d) -> (out, (new_state, new_x_last))."""
    B, S, d = x.shape
    x_prev = _shift(x, x_last)
    r, k, v, logw, u, g = _project(params, cfg, x, x_prev)
    if S % CHUNK == 0 and S > 1:
        y, Sf = wkv_chunked(r, k, v, logw, u, state)
    else:
        y, Sf = wkv_ref(r, k, v, logw, u) if state is None else \
            _wkv_ref_with_state(r, k, v, logw, u, state)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = common.layernorm(params["ln_x"], y) * g
    return mm(y, params["w_o"]), (Sf, x[:, -1])


def _wkv_ref_with_state(r, k, v, logw, u, S0):
    B, S, H, D = r.shape

    def step(S_, inp):
        r_, k_, v_, lw_ = inp
        kv = k_[..., :, None] * v_[..., None, :]
        y = jnp.einsum("bhd,bhde->bhe", r_, S_ + u[None, :, :, None] * kv)
        S_ = jnp.exp(lw_)[..., None] * S_ + kv
        return S_, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (r, k, v, logw))
    Sf, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), Sf


def channelmix_fwd(params, cfg: ModelConfig, x, x_last=None):
    x_prev = _shift(x, x_last)
    kx = _lerp(x, x_prev, params["cm_mu_k"])
    rx = _lerp(x, x_prev, params["cm_mu_r"])
    k = common.relu2(mm(kx, params["cm_w_k"]))
    out = jax.nn.sigmoid(mm(rx, params["cm_w_r"])) * mm(k, params["cm_w_v"])
    return out, x[:, -1]


def init_rwkv_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    hd = cfg.head_dim if cfg.head_dim else 64
    H = d // hd
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, d), jnp.float32),
        "x_cm": jnp.zeros((batch, d), jnp.float32),
    }
