"""RecurrentGemma / Griffin recurrent block (RG-LRU) [arXiv:2402.19427].

Block:  x -> { linear -> temporal conv1d -> RG-LRU }  * { linear -> GeLU }
          -> linear out

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)              recurrence gate
    i_t = sigmoid(W_x x_t + b_x)              input gate
    a_t = exp(c * r_t * log(sigmoid(Lambda)))   c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth parallel
prefix — the TPU-native algorithm; also keeps XLA FLOP accounting honest,
unlike a while-loop scan whose body is counted once).  Decode carries a
single (B, w) state.  The Pallas kernel (kernels/rglru_scan.py) is the
fused TPU hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import mm

RGLRU_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999]
    u = jax.random.uniform(ks[6], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_rec_in": common.dense_init(ks[0], (d, w), dtype),
        "w_gate_in": common.dense_init(ks[1], (d, w), dtype),
        "conv_w": common.dense_init(ks[2], (cfg.conv1d_width, w), dtype,
                                    scale=cfg.conv1d_width ** -0.5),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": common.dense_init(ks[3], (w, w), dtype),
        "b_a": jnp.zeros((w,), dtype),
        "w_x": common.dense_init(ks[4], (w, w), dtype),
        "b_x": jnp.zeros((w,), dtype),
        "lambda": lam.astype(dtype),
        "w_out": common.dense_init(ks[5], (w, d), dtype, scale=w ** -0.5),
    }


def _gates(params, u):
    """u: (..., w) post-conv activations -> (log_a, gated_input)."""
    r = jax.nn.sigmoid(mm(u, params["w_a"]) + params["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(mm(u, params["w_x"]) + params["b_x"].astype(u.dtype))
    log_a = (RGLRU_C * r.astype(jnp.float32)
             * jax.nn.log_sigmoid(params["lambda"].astype(jnp.float32)))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    bx = beta * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, bx


def _conv1d(params, x, state=None):
    """Depthwise causal temporal conv.  x: (B,S,w).  ``state``: (B,K-1,w)
    trailing inputs from the previous step (decode)."""
    K = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+K-1, w)
    out = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i].astype(x.dtype)
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out + params["conv_b"].astype(x.dtype), new_state


def rglru_fwd(params, cfg: ModelConfig, x, h0=None):
    """Full-sequence forward.  x: (B,S,d) -> (B,S,d).  ``h0``: (B,w) initial
    recurrent state (used by Split-FedLLM truncation and chunked prefill)."""
    u = mm(x, params["w_rec_in"])                           # (B,S,w)
    u, _ = _conv1d(params, u)
    a, bx = _gates(params, u)                               # (B,S,w) fp32
    if h0 is not None:
        # fold initial state in as a virtual step: h_t includes a-prefix * h0
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    gate = common.gelu(mm(x, params["w_gate_in"]))
    out = h.astype(x.dtype) * gate
    return mm(out, params["w_out"]), h[:, -1]


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width),
                          dtype),
    }


def rglru_decode(params, cfg: ModelConfig, x, cache):
    """One-token decode.  x: (B,1,d) -> ((B,1,d), new_cache)."""
    u = mm(x, params["w_rec_in"])
    u, conv_state = _conv1d(params, u, cache["conv"])
    a, bx = _gates(params, u)                               # (B,1,w)
    h = a[:, 0] * cache["h"] + bx[:, 0]                     # (B,w)
    gate = common.gelu(mm(x, params["w_gate_in"]))
    out = h[:, None].astype(x.dtype) * gate
    return mm(out, params["w_out"]), {"h": h, "conv": conv_state}
