"""Mixture-of-Experts MLP with sort-based capacity dispatch.

Tokens are routed top-k, assignments sorted by expert id, packed into a
static (E, C, d) buffer (capacity drop beyond C), and processed with an
expert-batched einsum ``ecd,edf->ecf`` — the expert dim shards cleanly on
the ``model`` mesh axis (expert parallelism) and the pack/unpack scatters
lower to the MoE all-to-all under SPMD.  No (T, E, C) one-hot tensor is
ever materialized (GShard-dispatch would be O(T*E*C) memory).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import hint, mm

def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {"router": common.dense_init(ks[0], (d, E), dtype, scale=d ** -0.5)}
    if cfg.activation == "swiglu":
        p["w_gate"] = common.dense_init(ks[1], (E, d, ff), dtype)
        p["w_in"] = common.dense_init(ks[2], (E, d, ff), dtype)
    else:
        p["w_in"] = common.dense_init(ks[2], (E, d, ff), dtype)
    p["w_out"] = common.dense_init(ks[3], (E, ff, d), dtype,
                                   scale=ff ** -0.5)
    return p


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Static per-expert buffer size.  Tokens routed beyond it are DROPPED
    (weight 0) — standard train-time capacity semantics; decode (T small)
    never drops.  cfg.moe_capacity_factor tunes the trade-off."""
    cap = math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                    * cfg.moe_capacity_factor)
    # align to 8 lanes only when the buffer is big enough to care; a floor
    # of 8 at decode (S=1, k assignments) wasted 32x expert compute
    # (SSPerf hillclimb 2, iteration C)
    return max(1, cap) if cap <= 8 else -(-cap // 8) * 8


def moe_fwd(params, cfg: ModelConfig, x):
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar).  Dispatch selected by
    cfg.moe_dispatch (SSPerf hillclimb 1):

    - "global":   flat sort across all tokens — simple, but under SPMD the
                  global argsort/gathers force all-gathers/all-reduces of
                  (T*k, d) buffers (829 GB/layer/device on qwen3-moe).
    - "batched":  per-batch-row sort — dispatch indexing is local to each
                  data shard (2.2x better, but GSPMD still all-gathers the
                  (B, E, C, d) buffer over the model axis).
    - "shard_map": explicit schedule.  Activations are replicated over the
                  model axis by the surrounding tensor-parallel layers, so
                  each model shard computes ONLY its expert slice on the
                  locally-packed buffer and a single psum((B,S,d)) merges
                  expert outputs — no dispatch-buffer collectives at all.
                  Falls back to "batched" when no mesh is ambient (CPU).
    """
    if cfg.moe_dispatch == "shard_map":
        mesh = _ambient_mesh()
        if mesh is not None and "model" in (mesh.axis_names or ()):
            return _moe_fwd_shard_map(params, cfg, x, mesh)
        return _moe_fwd_batched(params, cfg, x)
    if cfg.moe_dispatch == "batched":
        return _moe_fwd_batched(params, cfg, x)
    return _moe_fwd_global(params, cfg, x)


def _ambient_mesh():
    """The installed mesh (jax.set_mesh / ``with mesh:``), or None.
    ``get_abstract_mesh`` only exists on newer jax; older releases track
    the context-manager mesh in thread resources."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - jax internals moved
        return None


def _route_and_pack(params, cfg: ModelConfig, x):
    """Shared per-row routing/packing: returns (buf (B,E,C,d), sw, stok,
    keep, slot, aux).  All indexing is within-row -> shard-local when the
    batch dim is sharded."""
    B, S, d = x.shape
    k, E = cfg.top_k, cfg.n_experts
    C = expert_capacity(S, cfg)
    A = S * k

    logits = mm(x, params["router"]).astype(jnp.float32)       # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    flat_e = topk_e.reshape(B, A)
    rows = jnp.arange(B)[:, None]
    dispatch_frac = jnp.zeros((B, E), jnp.float32).at[
        rows, flat_e].add(1.0).mean(0) / (S * k)
    aux = E * jnp.sum(me * dispatch_frac) * cfg.router_aux_coef

    flat_w = topk_p.reshape(B, A).astype(x.dtype)
    flat_tok = jnp.arange(A, dtype=jnp.int32)[None] // k
    order = jnp.argsort(flat_e, axis=1)
    se = flat_e[rows, order]
    stok = jnp.broadcast_to(flat_tok, (B, A))[rows, order]
    sw = flat_w[rows, order]

    counts = jnp.zeros((B, E), jnp.int32).at[rows, se].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(A, dtype=jnp.int32)[None] - starts[rows, se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)

    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, slot].set(
        x[rows, stok])
    return buf[:, :-1].reshape(B, E, C, d), sw, stok, keep, slot, aux


def _moe_fwd_shard_map(params, cfg: ModelConfig, x, mesh):
    """Explicit expert-parallel schedule.

    E >= M: each model rank owns E/M experts (pure expert parallelism).
    E <  M: hybrid expert+ffn parallelism — each expert's ffn dim is split
    across G = M/E ranks (SwiGLU/GELU are elementwise in ff, and w_out is
    row-parallel in ff, so partial outputs simply add); the same single
    psum((B,S,d), "model") merges both expert slices and ff partials.
    (SSPerf hillclimbs 1 & 2, iterations 3/D.)
    """
    E = cfg.n_experts
    M = mesh.shape["model"]
    dp = tuple(a for a in mesh.axis_names if a != "model")
    from jax.sharding import PartitionSpec as P
    import jax.experimental.shard_map as _sm

    wg = params.get("w_gate")
    wi, wo = params["w_in"], params["w_out"]
    if E >= M:
        if E % M != 0:
            return _moe_fwd_batched(params, cfg, x)
        E_loc, G = E // M, 1
    else:
        # hybrid path re-lays-out expert weights (ffn split): amortized
        # over a train/prefill step, but at decode (S==1) the reshard
        # dominates — the per-row batched path wins there (hc2 iter D)
        if M % E != 0 or x.shape[1] == 1:
            return _moe_fwd_batched(params, cfg, x)
        E_loc, G = 1, M // E
        ff = wi.shape[-1]
        if ff % G != 0:
            return _moe_fwd_batched(params, cfg, x)
        ff_loc = ff // G
        # split the ffn dim into G contiguous per-rank slices
        wi = wi.reshape(E, cfg.d_model, G, ff_loc).transpose(
            0, 2, 1, 3).reshape(E * G, cfg.d_model, ff_loc)
        if wg is not None:
            wg = wg.reshape(E, cfg.d_model, G, ff_loc).transpose(
                0, 2, 1, 3).reshape(E * G, cfg.d_model, ff_loc)
        wo = wo.reshape(E, G, ff_loc, cfg.d_model).reshape(
            E * G, ff_loc, cfg.d_model)

    def local_fn(xl, router, wg_l, wi_l, wo_l):
        B, S, d = xl.shape
        C = expert_capacity(S, cfg)
        buf, sw, stok, keep, slot, aux = _route_and_pack(
            {"router": router}, cfg, xl)
        ridx = jax.lax.axis_index("model")
        eidx = ridx * E_loc if G == 1 else ridx // G   # first owned expert
        my = jax.lax.dynamic_slice_in_dim(buf, eidx, E_loc, 1)
        if cfg.activation == "swiglu":
            g = common.silu(jnp.einsum("becd,edf->becf", my,
                                       wg_l.astype(my.dtype)))
            h = jnp.einsum("becd,edf->becf", my, wi_l.astype(my.dtype)) * g
        else:
            h = common.gelu(jnp.einsum("becd,edf->becf", my,
                                       wi_l.astype(my.dtype)))
        ye = jnp.einsum("becf,efd->becd", h, wo_l.astype(h.dtype))
        yf = ye.reshape(B, E_loc * C, d)
        # local unpack: only assignments routed to MY expert(s) contribute
        lo = eidx * C
        local_slot = slot - lo
        mine = keep & (local_slot >= 0) & (local_slot < E_loc * C)
        rows = jnp.arange(B)[:, None]
        gathered = jnp.where(
            mine[..., None],
            yf[rows, jnp.clip(local_slot, 0, E_loc * C - 1)], 0.0)
        out = jnp.zeros((B, S, d), xl.dtype).at[rows, stok].add(
            gathered * sw[..., None])
        out = jax.lax.psum(out, "model")   # experts + ff partials merge
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return out, aux

    in_specs = (P(dp, None, None), P(),
                P("model", None, None) if wg is not None else P(),
                P("model", None, None), P("model", None, None))
    return _sm.shard_map(
        local_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(x, params["router"], wg, wi, wo)


def _moe_fwd_global(params, cfg: ModelConfig, x):
    B, S, d = x.shape
    T, k, E = B * S, cfg.top_k, cfg.n_experts
    C = expert_capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = mm(xt, params["router"]).astype(jnp.float32)      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, k)                   # (T,k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)  # renormalize

    # ---- load-balance auxiliary loss (Switch-style) ----------------------
    me = jnp.mean(probs, axis=0)                                # (E,)
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[topk_e.reshape(-1)].add(
        1.0) / (T * k)
    aux = E * jnp.sum(me * dispatch_frac) * cfg.router_aux_coef

    # ---- sort assignments by expert --------------------------------------
    flat_e = topk_e.reshape(T * k)
    flat_w = topk_p.reshape(T * k).astype(x.dtype)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]

    # position within each expert's contiguous group
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts                        # exclusive
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos < C                                              # capacity drop
    slot = jnp.where(keep, se * C + pos, E * C)                 # E*C = dropped

    # ---- pack -> expert compute -> unpack --------------------------------
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        xt[stok], mode="drop")
    xe = buf.reshape(E, C, d)
    xe = hint(xe, "model", None, None)
    if cfg.activation == "swiglu":
        g = common.silu(jnp.einsum("ecd,edf->ecf", xe,
                                   params["w_gate"].astype(xe.dtype)))
        h = jnp.einsum("ecd,edf->ecf", xe,
                       params["w_in"].astype(xe.dtype)) * g
    else:
        h = common.gelu(jnp.einsum("ecd,edf->ecf", xe,
                                   params["w_in"].astype(xe.dtype)))
    h = hint(h, "model", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(h.dtype))
    yf = ye.reshape(E * C, d)

    gathered = jnp.where(keep[:, None], yf[jnp.minimum(slot, E * C - 1)], 0.0)
    out = jnp.zeros((T, d), x.dtype).at[stok].add(gathered * sw[:, None])
    return out.reshape(B, S, d), aux


def _moe_fwd_batched(params, cfg: ModelConfig, x):
    """Per-row dispatch: every batch row sorts/packs its own S*k
    assignments, so under SPMD with batch sharded on the data axes the
    dispatch indexing is shard-local; the (B, E, C, d) expert buffer is
    then resharded B(data)->E(model) by a single all-to-all."""
    B, S, d = x.shape
    k, E = cfg.top_k, cfg.n_experts
    C = expert_capacity(S, cfg)           # capacity per ROW per expert
    A = S * k                             # assignments per row

    logits = mm(x, params["router"]).astype(jnp.float32)       # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, k)                   # (B,S,k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    flat_e = topk_e.reshape(B, A)
    dispatch_frac = jnp.zeros((B, E), jnp.float32).at[
        jnp.arange(B)[:, None], flat_e].add(1.0).mean(0) / (S * k)
    aux = E * jnp.sum(me * dispatch_frac) * cfg.router_aux_coef

    flat_w = topk_p.reshape(B, A).astype(x.dtype)
    flat_tok = jnp.arange(A, dtype=jnp.int32)[None] // k       # (1,A)
    order = jnp.argsort(flat_e, axis=1)                        # per-row sort
    rows = jnp.arange(B)[:, None]
    se = flat_e[rows, order]
    stok = jnp.broadcast_to(flat_tok, (B, A))[rows, order]
    sw = flat_w[rows, order]

    counts = jnp.zeros((B, E), jnp.int32).at[rows, se].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(A, dtype=jnp.int32)[None] - starts[rows, se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)

    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, slot].set(
        x[rows, stok])
    xe = buf[:, :-1].reshape(B, E, C, d)
    xe = hint(xe, ("pod", "data"), "model", None, None)   # the true a2a
    if cfg.activation == "swiglu":
        g = common.silu(jnp.einsum("becd,edf->becf", xe,
                                   params["w_gate"].astype(xe.dtype)))
        h = jnp.einsum("becd,edf->becf", xe,
                       params["w_in"].astype(xe.dtype)) * g
    else:
        h = common.gelu(jnp.einsum("becd,edf->becf", xe,
                                   params["w_in"].astype(xe.dtype)))
    ye = jnp.einsum("becf,efd->becd", h, params["w_out"].astype(h.dtype))
    ye = hint(ye, ("pod", "data"), "model", None, None)
    yf = ye.reshape(B, E * C, d)

    gathered = jnp.where(keep[..., None],
                         yf[rows, jnp.minimum(slot, E * C - 1)], 0.0)
    out = jnp.zeros((B, S, d), x.dtype).at[rows, stok].add(
        gathered * sw[..., None])
    return out, aux
