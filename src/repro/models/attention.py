"""Grouped-query attention with optional sliding window, qk-norm, QKV bias,
RoPE, KV caching (decode) and cross-attention (encoder-decoder).

Long sequences use a q-chunked ``lax.scan`` so the compiled program's live
score tensor is (B, H, chunk, S) rather than (B, H, S, S) — this is the
XLA path; the Pallas flash kernel (kernels/flash_attention.py) is the
TPU-target hot path validated against ref.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import common
from repro.models.common import NEG_INF, apply_rope, hint, mm

Q_CHUNK = 512          # q-chunk length above which we scan over q blocks


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig, cross: bool = False,
                   dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (d, h * hd), dtype),
        "wk": common.dense_init(ks[1], (d, kv * hd), dtype),
        "wv": common.dense_init(ks[2], (d, kv * hd), dtype),
        "wo": common.dense_init(ks[3], (h * hd, d), dtype,
                                scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


# --------------------------------------------------------------------------- #
# Core attend
# --------------------------------------------------------------------------- #
def _attend(q, k, v, mask) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D); mask: (Sq,Skv) or None.

    GQA via head-group reshape; fp32 softmax.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    scores = scores * (D ** -0.5)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def _attend_chunked(q, k, v, q_offset: int, window: int) -> jax.Array:
    """Causal (optionally windowed) attention with q-chunked scan."""
    B, Sq, H, D = q.shape
    n_chunks = Sq // Q_CHUNK
    rem = Sq % Q_CHUNK

    def body(_, qc_and_idx):
        qc, idx = qc_and_idx
        mask = common.causal_mask(qc.shape[1], k.shape[1],
                                  q_offset=q_offset + idx * Q_CHUNK,
                                  window=window)
        return None, _attend(qc, k, v, mask)

    if n_chunks:
        qs = q[:, : n_chunks * Q_CHUNK].reshape(B, n_chunks, Q_CHUNK, H, D)
        qs = jnp.moveaxis(qs, 1, 0)                   # (n, B, C, H, D)
        _, outs = jax.lax.scan(body, None,
                               (qs, jnp.arange(n_chunks)))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, n_chunks * Q_CHUNK, H, D)
    else:
        out = jnp.zeros((B, 0, H, D), q.dtype)
    if rem:
        mask = common.causal_mask(rem, k.shape[1],
                                  q_offset=q_offset + n_chunks * Q_CHUNK,
                                  window=window)
        out = jnp.concatenate([out, _attend(q[:, n_chunks * Q_CHUNK:],
                                            k, v, mask)], axis=1)
    return out


# --------------------------------------------------------------------------- #
# Full-sequence forward (train / prefill)
# --------------------------------------------------------------------------- #
def attention_fwd(params, cfg: ModelConfig, x, positions,
                  window: int = 0, use_rope: Optional[bool] = None):
    """x: (B,S,d) -> (B,S,d).  ``window``>0 -> sliding-window attention."""
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = mm(x, params["wq"])
    k = mm(x, params["wk"])
    v = mm(x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"].astype(q.dtype), \
            k + params["bk"].astype(k.dtype), v + params["bv"].astype(v.dtype)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if "q_norm" in params:
        q = common.rmsnorm({"scale": params["q_norm"]}, q)
        k = common.rmsnorm({"scale": params["k_norm"]}, k)
    rope = cfg.use_rope if use_rope is None else use_rope
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = hint(q, ("pod", "data"), None, "model", None)
    k = hint(k, ("pod", "data"), None, None, None)
    if kernel_ops.use_pallas():
        out = kernel_ops.mha_attention(q, k, v, causal=True, window=window)
    elif S > Q_CHUNK:
        out = _attend_chunked(q, k, v, 0, window)
    else:
        out = _attend(q, k, v, common.causal_mask(S, S, window=window))
    out = out.reshape(B, S, h * hd)
    return mm(out, params["wo"])


def attention_fwd_noncausal(params, cfg: ModelConfig, x, positions):
    """Bidirectional self-attention (whisper encoder)."""
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = mm(x, params["wq"]).reshape(B, S, h, hd)
    k = mm(x, params["wk"]).reshape(B, S, kv, hd)
    v = mm(x, params["wv"]).reshape(B, S, kv, hd)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if kernel_ops.use_pallas():
        out = kernel_ops.mha_attention(q, k, v, causal=False)
    else:
        out = _attend(q, k, v, None)
    return mm(out.reshape(B, S, h * hd), params["wo"])


def cross_attention_fwd(params, cfg: ModelConfig, x, enc_kv):
    """Decoder cross-attention.  enc_kv = (k, v) precomputed (B,Se,KV,D)."""
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = mm(x, params["wq"]).reshape(B, S, h, hd)
    k, v = enc_kv
    if kernel_ops.use_pallas():
        out = kernel_ops.mha_attention(q, k, v, causal=False)
    else:
        out = _attend(q, k, v, None)
    return mm(out.reshape(B, S, h * hd), params["wo"])


def encode_cross_kv(params, cfg: ModelConfig, enc_out):
    """Project encoder output once into cross-attn K/V."""
    B, Se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = mm(enc_out, params["wk"]).reshape(B, Se, kv, hd)
    v = mm(enc_out, params["wv"]).reshape(B, Se, kv, hd)
    return k, v


# --------------------------------------------------------------------------- #
# KV cache (decode)
# --------------------------------------------------------------------------- #
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: int = 0, dtype=jnp.bfloat16):
    """Ring-buffer cache when windowed; linear cache otherwise."""
    size = min(max_len, window) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def attention_decode(params, cfg: ModelConfig, x, cache, pos,
                     window: int = 0, use_rope: Optional[bool] = None):
    """One-token decode.  x: (B,1,d); pos: scalar int32 absolute position.

    Returns (out (B,1,d), new_cache).
    """
    B = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = mm(x, params["wq"])
    k = mm(x, params["wk"])
    v = mm(x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"].astype(q.dtype), \
            k + params["bk"].astype(k.dtype), v + params["bv"].astype(v.dtype)
    q = q.reshape(B, 1, h, hd)
    k = k.reshape(B, 1, kv, hd)
    v = v.reshape(B, 1, kv, hd)
    if "q_norm" in params:
        q = common.rmsnorm({"scale": params["q_norm"]}, q)
        k = common.rmsnorm({"scale": params["k_norm"]}, k)
    rope = cfg.use_rope if use_rope is None else use_rope
    if rope:
        posv = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % size, jnp.minimum(pos, size - 1))
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # validity: linear -> idx <= pos; ring -> all slots written once full
    idx = jnp.arange(size)
    if window:
        valid = idx < jnp.minimum(pos + 1, size)
    else:
        valid = idx <= pos
    G = h // kv
    qr = q.reshape(B, 1, kv, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr,
                        ck.astype(qr.dtype)).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(cv.dtype),
                     cv.astype(qr.dtype))
    out = out.reshape(B, 1, h * hd)
    return mm(out, params["wo"]), {"k": ck, "v": cv}
