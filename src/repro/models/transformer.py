"""Model assembly: decoder-only LM covering dense / MoE / hybrid / SSM /
VLM families, plus the encoder-decoder variant (whisper) in encdec.py.

Parameters layout (functional, nested dicts):

    {"embed": (V,d) [, "pos_embed": (P,d)] [, "img_proj": (di,d)],
     "blocks": tuple(block_tree_stacked_over_groups, ...)   # per pattern pos
     "tail":   tuple(block_tree, ...)                       # remainder layers
     "final_norm": ..., ["lm_head": (d,V)]}

The repeated trunk is a ``lax.scan`` over pattern groups (constant-size HLO
-> tractable 512-way SPMD compiles).  A *pattern group* is one repetition of
``cfg.layer_pattern`` (e.g. RecurrentGemma's (rglru, rglru, local_attn));
layers beyond the last full group live unstacked in ``tail``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, LOCAL_ATTN, RGLRU, RWKV6, ModelConfig)
from repro.models import attention, common, mlp, moe, rglru, rwkv6
from repro.models.common import hint


# --------------------------------------------------------------------------- #
# Block init / apply
# --------------------------------------------------------------------------- #
def init_block(key, cfg: ModelConfig, kind: str, cross: bool = False,
               dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": common.init_norm(cfg.norm, d, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["attn"] = attention.init_attention(ks[0], cfg, dtype=dtype)
    elif kind == RGLRU:
        p["attn"] = rglru.init_rglru(ks[0], cfg, dtype=dtype)
    elif kind == RWKV6:
        p["attn"] = rwkv6.init_rwkv6(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["xnorm"] = common.init_norm(cfg.norm, d, dtype)
        p["xattn"] = attention.init_attention(ks[2], cfg, cross=True,
                                              dtype=dtype)
    if kind != RWKV6:                       # rwkv6 block embeds channel-mix
        p["norm2"] = common.init_norm(cfg.norm, d, dtype)
        if cfg.is_moe:
            p["mlp"] = moe.init_moe(ks[1], cfg, dtype=dtype)
        else:
            p["mlp"] = mlp.init_mlp(ks[1], cfg, dtype=dtype)
    else:
        p["norm2"] = common.init_norm(cfg.norm, d, dtype)
    return p


def block_fwd(p, cfg: ModelConfig, kind: str, x, positions,
              enc_kv=None, causal: bool = True):
    """Full-sequence block application.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = common.apply_norm(cfg.norm, p["norm1"], x)
    if kind == ATTN:
        window = cfg.sliding_window if causal else 0
        a = attention.attention_fwd(p["attn"], cfg, h, positions,
                                    window=window) if causal else \
            attention.attention_fwd_noncausal(p["attn"], cfg, h, positions)
        x = x + a
    elif kind == LOCAL_ATTN:
        x = x + attention.attention_fwd(p["attn"], cfg, h, positions,
                                        window=cfg.local_window)
    elif kind == RGLRU:
        out, _ = rglru.rglru_fwd(p["attn"], cfg, h)
        x = x + out
    elif kind == RWKV6:
        out, _ = rwkv6.timemix_fwd(p["attn"], cfg, h)
        x = x + out
        h2 = common.apply_norm(cfg.norm, p["norm2"], x)
        cm, _ = rwkv6.channelmix_fwd(p["attn"], cfg, h2)
        return x + cm, aux
    if enc_kv is not None:
        hx = common.apply_norm(cfg.norm, p["xnorm"], x)
        x = x + attention.cross_attention_fwd(p["xattn"], cfg, hx, enc_kv)
    h = common.apply_norm(cfg.norm, p["norm2"], x)
    if cfg.is_moe:
        m, aux = moe.moe_fwd(p["mlp"], cfg, h)
    else:
        m = mlp.mlp_fwd(p["mlp"], cfg, h)
    x = x + m
    if "adapter" in p:                       # bottleneck adapter (PEFT)
        from repro.peft import adapters as _ad
        x = _ad.adapter_fwd(p["adapter"], x)
    return x, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if kind == ATTN:
        return attention.init_kv_cache(cfg, batch, max_len,
                                       window=cfg.sliding_window, dtype=dtype)
    if kind == LOCAL_ATTN:
        return attention.init_kv_cache(cfg, batch, max_len,
                                       window=cfg.local_window, dtype=dtype)
    if kind == RGLRU:
        return rglru.init_rglru_cache(cfg, batch)
    if kind == RWKV6:
        return rwkv6.init_rwkv_cache(cfg, batch)
    raise ValueError(kind)


def block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos, enc_kv=None):
    """One-token decode.  x: (B,1,d).  Returns (x, new_cache)."""
    h = common.apply_norm(cfg.norm, p["norm1"], x)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.sliding_window if kind == ATTN else cfg.local_window
        a, cache = attention.attention_decode(p["attn"], cfg, h, cache, pos,
                                              window=window)
        x = x + a
    elif kind == RGLRU:
        out, cache = rglru.rglru_decode(p["attn"], cfg, h, cache)
        x = x + out
    elif kind == RWKV6:
        out, (S, x_tm) = rwkv6.timemix_fwd(
            p["attn"], cfg, h, state=cache["S"], x_last=cache["x_tm"])
        x = x + out
        h2 = common.apply_norm(cfg.norm, p["norm2"], x)
        cm, x_cm = rwkv6.channelmix_fwd(p["attn"], cfg, h2,
                                        x_last=cache["x_cm"])
        return x + cm, {"S": S, "x_tm": x_tm.astype(jnp.float32),
                        "x_cm": x_cm.astype(jnp.float32)}
    if enc_kv is not None:
        hx = common.apply_norm(cfg.norm, p["xnorm"], x)
        x = x + attention.cross_attention_fwd(p["xattn"], cfg, hx, enc_kv)
    h = common.apply_norm(cfg.norm, p["norm2"], x)
    if cfg.is_moe:
        m, _ = moe.moe_fwd(p["mlp"], cfg, h)
    else:
        m = mlp.mlp_fwd(p["mlp"], cfg, h)
    return x + m, cache


# --------------------------------------------------------------------------- #
# Model init
# --------------------------------------------------------------------------- #
def _group_split(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, int]:
    """(pattern, n_full_groups, n_tail_layers)."""
    pat = cfg.layer_pattern or (ATTN,)
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.n_layers - n_groups * len(pat)
    return pat, n_groups, tail


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    pat, n_groups, n_tail = _group_split(cfg)
    keys = jax.random.split(key, 8)
    V, d = cfg.vocab_size, cfg.d_model
    params: Dict[str, Any] = {
        "embed": common.embed_init(keys[0], (V, d), dtype),
        "final_norm": common.init_norm(cfg.norm, d, dtype),
    }
    if not cfg.use_rope:
        params["pos_embed"] = common.embed_init(
            keys[1], (cfg.max_position_embeddings, d), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(keys[2], (d, V), dtype)
    if cfg.n_image_tokens:
        di = cfg.image_embed_dim or d
        params["img_proj"] = common.dense_init(keys[3], (di, d), dtype)

    cross = cfg.is_encoder_decoder
    blocks = []
    if n_groups > 0:
        for pi, kind in enumerate(pat):
            gkeys = jax.random.split(jax.random.fold_in(keys[4], pi),
                                     n_groups)
            init_one = functools.partial(init_block, cfg=cfg, kind=kind,
                                         cross=cross, dtype=dtype)
            blocks.append(jax.vmap(lambda k: init_one(k))(gkeys))
    params["blocks"] = tuple(blocks)
    tail = []
    for ti in range(n_tail):
        kind = pat[ti % len(pat)]
        tail.append(init_block(jax.random.fold_in(keys[5], ti), cfg, kind,
                               cross=cross, dtype=dtype))
    params["tail"] = tuple(tail)
    return params


# --------------------------------------------------------------------------- #
# Embedding & head
# --------------------------------------------------------------------------- #
def embed_tokens(params, cfg: ModelConfig, tokens, img_embeds=None,
                 prefix_embeds=None, pos_offset: int = 0):
    """tokens: (B,S) int32 -> (h (B,S',d), positions (B,S')).  For VLMs the
    projected image embeddings are prepended (S' = n_img + S); soft-prompt
    prefixes (peft/prompt.py) are prepended unprojected."""
    h = params["embed"][tokens]                         # gather, (B,S,d)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if img_embeds is not None:
        proj = common.mm(img_embeds.astype(h.dtype), params["img_proj"])
        h = jnp.concatenate([proj, h], axis=1)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S = h.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)[None] + pos_offset
    positions = jnp.broadcast_to(positions, (B, S))
    if not cfg.use_rope:
        h = h + params["pos_embed"][positions[0]][None]
    return h, positions


def lm_logits(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    h = hint(h, ("pod", "data"), None, None)
    return common.mm(h, w)


# --------------------------------------------------------------------------- #
# Full forward (train / prefill)
# --------------------------------------------------------------------------- #
def forward(params, cfg: ModelConfig, tokens, img_embeds=None,
            prefix_embeds=None, scan_layers: bool = True,
            remat: str = "none"):
    """Returns (logits (B,S',V), aux_loss)."""
    pat, n_groups, _ = _group_split(cfg)
    h, positions = embed_tokens(params, cfg, tokens, img_embeds,
                                prefix_embeds)
    h = hint(h, ("pod", "data"), None, None)
    aux0 = jnp.zeros((), jnp.float32)

    def apply_group(h, aux, group_params):
        for pi, kind in enumerate(pat):
            h, a = block_fwd(group_params[pi], cfg, kind, h, positions)
            aux = aux + a
        return h, aux

    if remat == "selective":
        # save matmul outputs, recompute elementwise ops only: cuts the
        # backward recompute traffic that makes full remat memory-bound
        # (SSPerf hillclimb 3)
        apply_group = jax.checkpoint(
            apply_group,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat != "none":
        apply_group = jax.checkpoint(apply_group)

    if scan_layers and n_groups > 0:
        def body(carry, gp):
            h, aux = carry
            h, aux = apply_group(h, aux, gp)
            return (h, aux), None

        (h, aux), _ = jax.lax.scan(body, (h, aux0), params["blocks"])
    else:
        aux = aux0
        for g in range(n_groups):
            gp = jax.tree.map(lambda x: x[g], params["blocks"])
            h, aux = apply_group(h, aux, gp)
    for ti, tp in enumerate(params["tail"]):
        kind = pat[ti % len(pat)]
        h, a = block_fwd(tp, cfg, kind, h, positions)
        aux = aux + a
    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    return lm_logits(params, cfg, h), aux


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    pat, n_groups, n_tail = _group_split(cfg)
    stacked = []
    if n_groups > 0:
        for pi, kind in enumerate(pat):
            one = init_block_cache(cfg, kind, batch, max_len, dtype)
            stacked.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
                one))
    tail = tuple(
        init_block_cache(cfg, pat[ti % len(pat)], batch, max_len, dtype)
        for ti in range(n_tail))
    return {"blocks": tuple(stacked), "tail": tail}


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """token: (B,) int32; pos: scalar int32.  Returns (logits (B,V), cache)."""
    pat, n_groups, _ = _group_split(cfg)
    h = params["embed"][token][:, None]                  # (B,1,d)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if not cfg.use_rope:
        h = h + params["pos_embed"][pos][None, None]

    def body(h, xs):
        gp, gc = xs
        new_c = []
        for pi, kind in enumerate(pat):
            h, c = block_decode(gp[pi], cfg, kind, h, gc[pi], pos)
            new_c.append(c)
        return h, tuple(new_c)

    if n_groups > 0:
        h, new_blocks = jax.lax.scan(body, h,
                                     (params["blocks"], cache["blocks"]))
    else:
        new_blocks = cache["blocks"]
    new_tail = []
    for ti, tp in enumerate(params["tail"]):
        kind = pat[ti % len(pat)]
        h, c = block_decode(tp, cfg, kind, h, cache["tail"][ti], pos)
        new_tail.append(c)
    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    logits = lm_logits(params, cfg, h)[:, 0]
    return logits, {"blocks": new_blocks, "tail": tuple(new_tail)}


# --------------------------------------------------------------------------- #
# Layer-range application (Split-FedLLM)
# --------------------------------------------------------------------------- #
def n_groups_of(cfg: ModelConfig) -> int:
    _, n_groups, _ = _group_split(cfg)
    return n_groups


def slice_groups(params, start: int, end: int):
    """Sub-model params covering pattern groups [start, end)."""
    out = dict(params)
    out["blocks"] = jax.tree.map(lambda x: x[start:end], params["blocks"])
    if start > 0:
        out.pop("embed", None)
    return out


def forward_groups(params, cfg: ModelConfig, h, positions, start: int,
                   end: int, include_tail: bool = False):
    """Apply pattern groups [start, end) (already-embedded hidden h)."""
    pat, n_groups, _ = _group_split(cfg)
    aux = jnp.zeros((), jnp.float32)
    for g in range(start, end):
        gp = jax.tree.map(lambda x: x[g], params["blocks"])
        for pi, kind in enumerate(pat):
            h, a = block_fwd(gp[pi], cfg, kind, h, positions)
            aux = aux + a
    if include_tail:
        for ti, tp in enumerate(params["tail"]):
            kind = pat[ti % len(pat)]
            h, a = block_fwd(tp, cfg, kind, h, positions)
            aux = aux + a
    return h, aux
