"""Encoder-decoder assembly (whisper-base backbone [arXiv:2212.04356]).

The mel-spectrogram + conv2 frontend is a STUB per the charter: the
encoder consumes precomputed frame embeddings (B, S_enc, d) delivered by
``input_specs()``.  Encoder: bidirectional attention blocks.  Decoder:
causal self-attention + cross-attention blocks (built by transformer.py
with ``cross=True``); cross-attention K/V are projected once from the
encoder output and reused across decode steps.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models import attention, common, transformer
from repro.models.common import mm


def init_encoder(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 2)
    gkeys = jax.random.split(keys[0], cfg.n_encoder_layers)

    def init_one(k):
        return transformer.init_block(k, cfg, ATTN, cross=False, dtype=dtype)

    stacked = jax.vmap(init_one)(gkeys)
    return {"blocks": stacked,
            "norm": common.init_norm(cfg.norm, cfg.d_model, dtype)}


def init_encdec_params(key, cfg: ModelConfig, dtype=jnp.float32):
    k_enc, k_dec = jax.random.split(key)
    params = transformer.init_params(k_dec, cfg, dtype)
    params["encoder"] = init_encoder(k_enc, cfg, dtype)
    return params


def encode(params, cfg: ModelConfig, enc_embeds):
    """enc_embeds: (B, S_enc, d) stub frontend output -> encoder states."""
    B, Se, d = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
    h = enc_embeds + common.sinusoidal_positions(Se, d).astype(
        enc_embeds.dtype)[None]

    def body(h, bp):
        hn = common.apply_norm(cfg.norm, bp["norm1"], h)
        h = h + attention.attention_fwd_noncausal(bp["attn"], cfg, hn, pos)
        hn = common.apply_norm(cfg.norm, bp["norm2"], h)
        from repro.models import mlp as _mlp
        h = h + _mlp.mlp_fwd(bp["mlp"], cfg, hn)
        return h, None

    h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
    return common.apply_norm(cfg.norm, params["encoder"]["norm"], h)


def _cross_kvs(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross-attn K/V (stacked for scan)."""
    pat, n_groups, _ = transformer._group_split(cfg)
    assert pat == (ATTN,), "enc-dec supports homogeneous attn decoders"

    def proj(xattn_p):
        return attention.encode_cross_kv(xattn_p, cfg, enc_out)

    stacked = jax.vmap(proj, in_axes=(0,))(params["blocks"][0]["xattn"])
    tail = tuple(proj(tp["xattn"]) for tp in params["tail"])
    return stacked, tail


def encdec_forward(params, cfg: ModelConfig, tokens, enc_embeds,
                   scan_layers: bool = True):
    """Training / scoring forward.  Returns (logits, aux)."""
    enc_out = encode(params, cfg, enc_embeds)
    return decode_given_enc(params, cfg, tokens, enc_out)


def decode_given_enc(params, cfg: ModelConfig, tokens, enc_out):
    """Decoder stack given precomputed encoder states (the Split-FedLLM
    boundary for encoder-decoder archs: client=encoder, server=decoder)."""
    xkv_stacked, xkv_tail = _cross_kvs(params, cfg, enc_out)
    h, positions = transformer.embed_tokens(params, cfg, tokens)
    aux = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        h, aux = carry
        gp, xkv = xs
        h, a = transformer.block_fwd(gp[0], cfg, ATTN, h, positions,
                                     enc_kv=xkv)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, aux),
                               (params["blocks"], xkv_stacked))
    for tp, xkv in zip(params["tail"], xkv_tail):
        h, a = transformer.block_fwd(tp, cfg, ATTN, h, positions, enc_kv=xkv)
        aux = aux + a
    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    return transformer.lm_logits(params, cfg, h), aux


def init_encdec_cache(params, cfg: ModelConfig, batch: int, max_len: int,
                      enc_embeds, dtype=jnp.bfloat16):
    """Decode cache = self-attn KV cache + precomputed cross K/V."""
    cache = transformer.init_cache(cfg, batch, max_len, dtype)
    enc_out = encode(params, cfg, enc_embeds)
    cache["xkv"], cache["xkv_tail"] = _cross_kvs(params, cfg, enc_out)
    return cache


def encdec_decode_step(params, cfg: ModelConfig, cache, token, pos):
    h = params["embed"][token][:, None]
    if not cfg.use_rope:
        h = h + params["pos_embed"][pos][None, None]

    def body(h, xs):
        gp, gc, xkv = xs
        h, c = transformer.block_decode(gp[0], cfg, ATTN, h, gc[0], pos,
                                        enc_kv=xkv)
        return h, (c,)

    h, new_blocks = jax.lax.scan(
        body, h, (params["blocks"], cache["blocks"], cache["xkv"]))
    new_tail = []
    for ti, tp in enumerate(params["tail"]):
        h, c = transformer.block_decode(tp, cfg, ATTN, h, cache["tail"][ti],
                                        pos, enc_kv=cache["xkv_tail"][ti])
        new_tail.append(c)
    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    logits = transformer.lm_logits(params, cfg, h)[:, 0]
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    new_cache["tail"] = tuple(new_tail)
    return logits, new_cache
