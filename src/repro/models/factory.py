"""Model facade: one API over all families.

    model = build_model(cfg)
    params = model.init(key, dtype)
    logits, aux = model.forward(params, batch)      # train / prefill
    cache = model.init_cache(params, batch_size, max_len, batch)
    logits, cache = model.decode_step(params, cache, token, pos)

``batch`` is a dict: {"tokens": (B,S)} plus family extras
("img_embeds" for VLM, "enc_embeds" for audio).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ #
    def init(self, key, dtype=jnp.float32):
        if self.cfg.is_encoder_decoder:
            return encdec.init_encdec_params(key, self.cfg, dtype)
        return transformer.init_params(key, self.cfg, dtype)

    def init_abstract(self, dtype=jnp.float32):
        """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
        key = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.init(k, dtype), key)

    # ------------------------------------------------------------------ #
    def forward(self, params, batch: Dict[str, Any], scan_layers: bool = True,
                remat: str = "none"):
        from repro.kernels import ops as kernel_ops
        with kernel_ops.policy_scope(self.cfg.kernel_policy):
            if self.cfg.is_encoder_decoder:
                return encdec.encdec_forward(params, self.cfg,
                                             batch["tokens"],
                                             batch["enc_embeds"],
                                             scan_layers=scan_layers)
            return transformer.forward(
                params, self.cfg, batch["tokens"],
                img_embeds=batch.get("img_embeds"),
                prefix_embeds=batch.get("prefix_embeds"),
                scan_layers=scan_layers, remat=remat)

    # ------------------------------------------------------------------ #
    def init_cache(self, params, batch_size: int, max_len: int,
                   batch: Optional[Dict[str, Any]] = None,
                   dtype=jnp.bfloat16):
        if self.cfg.is_encoder_decoder:
            assert batch is not None and "enc_embeds" in batch
            return encdec.init_encdec_cache(params, self.cfg, batch_size,
                                            max_len, batch["enc_embeds"],
                                            dtype)
        return transformer.init_cache(self.cfg, batch_size, max_len, dtype)

    def decode_step(self, params, cache, token, pos):
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_decode_step(params, self.cfg, cache, token,
                                             pos)
        return transformer.decode_step(params, self.cfg, cache, token, pos)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
