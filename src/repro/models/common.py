"""Shared model building blocks: norms, RoPE, activations, initializers,
and mesh-aware sharding hints.

All models are *functional*: parameters are nested dicts of jnp arrays,
``init_*`` builds them from a PRNG key, ``apply``-style functions are pure.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------- #
# Sharding hints
# --------------------------------------------------------------------------- #
_HINTS_ENABLED = False


def enable_shard_hints(on: bool = True) -> None:
    global _HINTS_ENABLED
    _HINTS_ENABLED = on


@contextlib.contextmanager
def shard_hints(on: bool = True):
    global _HINTS_ENABLED
    prev = _HINTS_ENABLED
    _HINTS_ENABLED = on
    try:
        yield
    finally:
        _HINTS_ENABLED = prev


def hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint when a mesh is ambient; no-op otherwise."""
    if not _HINTS_ENABLED:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:                                     # no mesh / bad axes
        return x


# --------------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------------- #
def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LLM default)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return out.astype(dt)


def init_norm(kind: str, d: int, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(
        d, dtype)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# --------------------------------------------------------------------------- #
# Activations
# --------------------------------------------------------------------------- #
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def relu2(x):
    """Squared ReLU (Nemotron-4)."""
    r = jax.nn.relu(x)
    return r * r


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                           # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n_pos, d)."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2 - 1 + 1e-9))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# LoRA-aware matmul
# --------------------------------------------------------------------------- #
def mm(x: jax.Array, w) -> jax.Array:
    """Projection that accepts either a plain weight array or a LoRA-bound
    leaf ``{"w": W, "a": A, "b": B}`` (scale and dropout mask are folded
    into a/b at bind time so every leaf is a plain array — required for
    scan-over-stacked-layers).

    The LoRA path computes ``x@W + (x@A)@B`` without materializing
    ``W + BA`` — gradients flow to A/B only when W is a closed-over constant
    (see core/fedavg.train_step).  Dispatch between the XLA einsum chain
    and the fused differentiable Pallas ``lora_matmul`` kernel lives in
    peft/lora.lora_apply, driven by the ambient kernel policy
    (``ModelConfig.kernel_policy`` via kernels/ops.policy_scope).
    """
    if isinstance(w, dict) and "a" in w:
        from repro.peft.lora import lora_apply
        return lora_apply(x, w["w"], w["a"], w["b"])
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


# --------------------------------------------------------------------------- #
# Masking helpers
# --------------------------------------------------------------------------- #
NEG_INF = -1e30


def causal_mask(q_len: int, kv_len: int, q_offset=0,
                window: int = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask; True = attend.  ``q_offset`` is the
    absolute position of the first query (decode / chunked prefill).
    ``window`` > 0 restricts to a trailing sliding window."""
    q_pos = jnp.arange(q_len) + q_offset
    kv_pos = jnp.arange(kv_len)
    m = kv_pos[None, :] <= q_pos[:, None]
    if window:
        m = m & (kv_pos[None, :] > q_pos[:, None] - window)
    return m
