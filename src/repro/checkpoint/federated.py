"""Round-level crash recovery for the federated driver.

``save_run`` snapshots *everything mutable* in a
``core/round_program.run_program`` run after round ``rnd`` completes:
the program's global state (LoRA trees / KD teacher state / split
halves and server optimizer), the schedule's in-flight payloads and
participation RNGs, the secure-agg session (cohorts + fixed-point
vectors, bit-exact), the CommLedger, metric history, per-client cost
and DP release counters.  ``restore_run`` rebuilds all of it and hands
back the round to resume from, so a killed run resumed from its last
checkpoint finishes **bit-identical** to an uninterrupted one
(tests/test_faults.py pins ledger bytes, history and final params).

Everything *derivable* from ``FedConfig.seed`` — fault plans, local
dropout keys, DP noise keys, batch orders, secure-agg pair masks — is a
pure function of (seed, round, client) by construction (core/rng), so
it never needs to be stored: replay after resume regenerates it
exactly.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.checkpoint.manager import CheckpointManager
from repro.core import metrics as M


def save_run(mgr: CheckpointManager, ctx, program, schedule, rnd: int,
             rollovers: int) -> str:
    """Snapshot the run after round ``rnd`` (resume continues at
    ``rnd + 1``)."""
    from repro.core.async_agg import _Job  # noqa: F401  (restore twin)

    state = {
        "round": int(rnd) + 1,
        "rollovers": int(rollovers),
        "program": program.state_dict(ctx),
        "jobs": [{"client": int(j.client), "start": int(j.start),
                  "arrival": int(j.arrival), "payload": j.payload}
                 for j in schedule.jobs()],
        # numpy Generator states: nested dicts of strings and (big)
        # python ints — JSON round-trips them exactly
        "sched_rngs": schedule.rng_state(),
        "secagg": ctx.secagg.state_dict(),
        "ledger": {
            "default_hop": ctx.ledger.default_hop,
            "events": [[int(e.round), int(e.client), e.name, e.direction,
                        int(e.bytes), e.hop] for e in ctx.ledger.events],
        },
        "history": [[int(m.round), float(m.accuracy), float(m.loss),
                     float(m.comm_bytes_per_client), float(m.client_flops),
                     float(m.epsilon)] for m in ctx.history],
        "cost": [float(c.flops) for c in ctx.cost],
        "releases": [int(r) for r in ctx.releases],
        "cohort_ids": {f"{r}:{c}": int(v)
                       for (r, c), v in ctx._cohort_ids.items()},
    }
    return mgr.save_state(rnd + 1, state,
                          metadata={"framework": ctx.fed.framework,
                                    "rounds": int(ctx.fed.rounds)})


def restore_run(directory: str, ctx, program, schedule,
                step: Optional[int] = None) -> Tuple[int, int]:
    """Load the latest (or ``step``-th) snapshot from ``directory`` into
    a freshly constructed run -> (start_round, rollovers)."""
    from repro.core.async_agg import _Job

    st, _ = CheckpointManager(directory).restore_state(step)
    program.load_state_dict(ctx, st["program"])
    schedule.load_jobs([_Job(int(j["client"]), int(j["start"]),
                             int(j["arrival"]), j["payload"])
                        for j in st["jobs"]])
    if st["sched_rngs"] is not None:
        schedule.load_rng_state(st["sched_rngs"])
    ctx.secagg.load_state_dict(st["secagg"])
    ctx.ledger.default_hop = st["ledger"]["default_hop"]
    ctx.ledger.events = [M.CommEvent(r, c, name, d, b, hop)
                         for r, c, name, d, b, hop
                         in st["ledger"]["events"]]
    ctx.history[:] = [M.RoundMetrics(r, acc, loss, cb, fl, epsilon=eps)
                      for r, acc, loss, cb, fl, eps in st["history"]]
    for c, fl in zip(ctx.cost, st["cost"]):
        c.flops = fl
    ctx.releases[:] = [int(r) for r in st["releases"]]
    ctx._cohort_ids = {}
    for key, v in st["cohort_ids"].items():
        r, c = key.split(":")
        ctx._cohort_ids[(int(r), int(c))] = int(v)
    return int(st["round"]), int(st["rollovers"])
