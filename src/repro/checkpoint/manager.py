"""Step-indexed checkpoint manager with retention."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

from repro.checkpoint import serialization

_FMT = "ckpt_{step:08d}.npz"
_RE = re.compile(r"ckpt_(\d{8})\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        path = os.path.join(self.dir, _FMT.format(step=step))
        serialization.save_npz(path, tree)
        if metadata is not None:
            with open(path + ".json", "w") as f:
                json.dump(metadata, f)
        self._gc()
        return path

    def save_state(self, step: int, state,
                   metadata: Optional[dict] = None) -> str:
        """Dtype-exact, template-free snapshot (bit-exact crash
        recovery): arrays land in the npz, the structure manifest and
        any python-scalar state land in the json sidecar.  Shares the
        step naming and retention policy with ``save``."""
        import io

        import numpy as np

        path = os.path.join(self.dir, _FMT.format(step=step))
        manifest, arrays = serialization.state_flatten(state)
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        with open(path, "wb") as f:
            f.write(buf.getvalue())
        with open(path + ".json", "w") as f:
            json.dump({"manifest": manifest, "meta": metadata}, f)
        self._gc()
        return path

    def restore_state(self, step: Optional[int] = None):
        """-> (state, metadata) saved by ``save_state`` (latest step by
        default)."""
        import numpy as np

        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, _FMT.format(step=step))
        with open(path + ".json") as f:
            doc = json.load(f)
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        return serialization.state_unflatten(doc["manifest"], arrays), \
            doc.get("meta")

    def steps(self):
        out = []
        for fn in os.listdir(self.dir):
            m = _RE.match(fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: Optional[int] = None):
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, _FMT.format(step=step))
        tree = serialization.load_npz(path, template)
        meta_path = path + ".json"
        meta: Any = None
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
        return tree, meta

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            p = os.path.join(self.dir, _FMT.format(step=s))
            os.remove(p)
            if os.path.exists(p + ".json"):
                os.remove(p + ".json")
