"""Pytree <-> flat-npz serialization (no orbax in this environment).

Paths are '/'-joined key strings; tuples use integer segments.  Restores
into an identically-structured template tree."""
from __future__ import annotations

import io
from typing import Any, Dict

import jax
import numpy as np


def flatten_tree(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}

    def rec(t, path):
        if isinstance(t, dict):
            for k in sorted(t):
                rec(t[k], f"{path}/{k}" if path else str(k))
        elif isinstance(t, (tuple, list)):
            for i, v in enumerate(t):
                rec(v, f"{path}/{i}" if path else str(i))
        elif t is None:
            pass
        else:
            arr = np.asarray(t)
            if arr.dtype.kind not in "biufc":     # ml_dtypes (bf16 etc.):
                arr = np.asarray(t, np.float32)   # npz can't round-trip them
            out[path] = arr

    rec(tree, prefix)
    return out


def unflatten_into(template, flat: Dict[str, np.ndarray], prefix: str = ""):
    """Rebuild a tree shaped like ``template`` from ``flat``."""

    def rec(t, path):
        if isinstance(t, dict):
            return {k: rec(t[k], f"{path}/{k}" if path else str(k))
                    for k in t}
        if isinstance(t, (tuple, list)):
            return tuple(rec(v, f"{path}/{i}" if path else str(i))
                         for i, v in enumerate(t))
        if t is None:
            return None
        arr = flat[path]
        return jax.numpy.asarray(arr).astype(t.dtype) if hasattr(
            t, "dtype") else arr

    return rec(template, prefix)


def save_npz(path: str, tree) -> int:
    flat = flatten_tree(tree)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    data = buf.getvalue()
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_npz(path: str, template):
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return unflatten_into(template, flat)
