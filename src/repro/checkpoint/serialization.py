"""Pytree <-> flat-npz serialization (no orbax in this environment).

Paths are '/'-joined key strings; tuples use integer segments.  Restores
into an identically-structured template tree."""
from __future__ import annotations

import io
from typing import Any, Dict

import jax
import numpy as np


def flatten_tree(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}

    def rec(t, path):
        if isinstance(t, dict):
            for k in sorted(t):
                rec(t[k], f"{path}/{k}" if path else str(k))
        elif isinstance(t, (tuple, list)):
            for i, v in enumerate(t):
                rec(v, f"{path}/{i}" if path else str(i))
        elif t is None:
            pass
        else:
            arr = np.asarray(t)
            if arr.dtype.kind not in "biufc":     # ml_dtypes (bf16 etc.):
                arr = np.asarray(t, np.float32)   # npz can't round-trip them
            out[path] = arr

    rec(tree, prefix)
    return out


def unflatten_into(template, flat: Dict[str, np.ndarray], prefix: str = ""):
    """Rebuild a tree shaped like ``template`` from ``flat``."""

    def rec(t, path):
        if isinstance(t, dict):
            return {k: rec(t[k], f"{path}/{k}" if path else str(k))
                    for k in t}
        if isinstance(t, (tuple, list)):
            return tuple(rec(v, f"{path}/{i}" if path else str(i))
                         for i, v in enumerate(t))
        if t is None:
            return None
        arr = flat[path]
        return jax.numpy.asarray(arr).astype(t.dtype) if hasattr(
            t, "dtype") else arr

    return rec(template, prefix)


# --------------------------------------------------------------------------- #
# Template-free, dtype-exact state serialization (checkpoint/federated.py)
# --------------------------------------------------------------------------- #
# ``flatten_tree`` needs a template to restore into and downcasts
# ml_dtypes leaves to fp32 — fine for model snapshots, fatal for
# bit-exact crash recovery.  ``state_flatten``/``state_unflatten``
# instead carry a JSON *manifest* of the tree structure alongside the
# arrays: dict/tuple/list/None nodes and python scalars live in the
# manifest, array leaves keep their exact dtype (non-numpy dtypes such
# as bfloat16 are stored as raw-bit unsigned views and re-viewed on
# load), and each leaf records whether it was a jax or numpy array so
# restore hands back the same kind.
def state_flatten(state):
    """-> (manifest, {name: np.ndarray}) for ``np.savez`` + json."""
    arrays: Dict[str, np.ndarray] = {}
    counter = iter(range(1 << 30))

    def rec(t):
        if t is None:
            return {"t": "none"}
        if isinstance(t, dict):
            items = list(t.items())
            return {"t": "dict", "k": [k for k, _ in items],
                    "v": [rec(v) for _, v in items]}
        if isinstance(t, tuple):
            return {"t": "tuple", "v": [rec(x) for x in t]}
        if isinstance(t, list):
            return {"t": "list", "v": [rec(x) for x in t]}
        if isinstance(t, (bool, int, float, str)):
            return {"t": "py", "v": t}
        is_jax = isinstance(t, jax.Array)
        arr = np.asarray(t)
        node: Dict[str, Any] = {"t": "arr", "id": f"a{next(counter)}",
                                "jax": is_jax}
        if arr.dtype.kind not in "biufc":
            node["dtype"] = arr.dtype.name        # e.g. "bfloat16"
            view = np.dtype(f"u{arr.dtype.itemsize}") \
                if arr.dtype.itemsize in (1, 2, 4, 8) else np.uint8
            arr = arr.view(view)
        arrays[node["id"]] = arr
        return node

    return rec(state), arrays


def state_unflatten(manifest, arrays: Dict[str, np.ndarray]):
    """Inverse of ``state_flatten`` (manifest may have round-tripped
    through JSON)."""

    def rec(n):
        t = n["t"]
        if t == "none":
            return None
        if t == "dict":
            return {k: rec(v) for k, v in zip(n["k"], n["v"])}
        if t == "tuple":
            return tuple(rec(x) for x in n["v"])
        if t == "list":
            return [rec(x) for x in n["v"]]
        if t == "py":
            return n["v"]
        arr = arrays[n["id"]]
        if "dtype" in n:
            arr = arr.view(np.dtype(n["dtype"]))
        return jax.numpy.asarray(arr) if n["jax"] else arr

    return rec(manifest)


def save_npz(path: str, tree) -> int:
    flat = flatten_tree(tree)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    data = buf.getvalue()
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_npz(path: str, template):
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return unflatten_into(template, flat)
