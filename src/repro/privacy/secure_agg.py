"""Simulated secure aggregation (``PrivacyConfig.secure_agg``).

Bonawitz-style pairwise additive masking, simulated faithfully enough
to pin its two load-bearing properties in tests while staying
bit-transparent to the training math:

- **Exact mask cancellation.**  Each upload is encoded as a fixed-point
  uint64 vector; every unordered client pair (i, j) of a masking cohort
  shares a seeded mask vector m_ij, added by i and subtracted by j
  (mod 2^64).  At every aggregation event the session recomputes the
  masked sum of the delivered subset, removes the recovered masks of
  absent members, and asserts it equals the plain fixed-point sum
  *exactly* — uint64 wraparound arithmetic, no tolerance.

- **Wire accounting.**  Key exchange (cohort setup) and dropout
  recovery (mask reconstruction for members absent from an aggregation
  event) are charged to the CommLedger under ``secagg_keys`` /
  ``secagg_recovery``, so Fig. 4 reports the cost of privacy.  The
  byte model: every cohort member uploads one 32-byte public key plus
  an encrypted 32-byte seed share per peer, downloads the peers' keys
  and shares; each delivered client uploads one 32-byte share per
  member absent from that event.

The *model update* consumes the original float payloads: the simulation
treats the fixed-point encoding as lossless transport (a real
deployment would dequantize the masked sum and eat the rounding error),
which keeps ``secure_agg=True, noise=0`` bit-exact with the plain
engines — the acceptance property tests/test_privacy.py pins across
every framework x backend x aggregation combination.

Masking cohorts are *start* cohorts: the clients that pull the global
state in the same round mask against each other, because that is when
payloads are created.  Under async aggregation a cohort's members
deliver across different rounds, so every aggregation event recovers
the masks of the cohort members it is missing — the dropout/recovery
path exercised whenever ``ParticipationSchedule`` spreads deliveries.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.core import metrics as M

KEY_BYTES = 32      # one DH public key
SHARE_BYTES = 32    # one encrypted Shamir share of a mask seed

_PAIR_STREAM = 0xA55A  # domain separator for pairwise mask seeds


def flat_fixed_point(payload, frac_bits: int) -> np.ndarray:
    """Flatten a payload (pytree or array) to a fixed-point uint64
    vector: round(x * 2^frac_bits) in two's complement."""
    leaves = [np.asarray(x, np.float64).ravel()
              for x in jax.tree.leaves(payload)]
    flat = np.concatenate(leaves) if leaves else np.zeros(0, np.float64)
    # fault-injected NaN/Inf payloads pass through here before the
    # validation seam quarantines them — silence the cast warning, the
    # (garbage) encoding is discarded with the payload
    with np.errstate(invalid="ignore"):
        return np.round(flat * float(1 << frac_bits)).astype(
            np.int64).astype(np.uint64)


class SecureAggSession:
    """One masking session per federated run.  Every method is a no-op
    when ``fed.privacy.secure_agg`` is False, so engines call it
    unconditionally."""

    def __init__(self, fed: FedConfig):
        self.priv = fed.privacy
        self.enabled = bool(self.priv.secure_agg)
        self._seed = (fed.seed, self.priv.seed, _PAIR_STREAM)
        self._cohorts: Dict[int, List[int]] = {}      # start round -> cis
        self._plain: Dict[Tuple[int, int], np.ndarray] = {}
        self._size: Dict[int, int] = {}               # cohort mask length

    # -- cohort setup ------------------------------------------------------ #
    def begin_cohort(self, ledger: M.CommLedger, rnd: int,
                     cohort: Iterable[int], cohort_id: int = None):
        """Key/share exchange for the clients starting a job this round
        (sync: everyone, every round).  Records the exchange bytes.

        ``cohort_id`` keys the masking cohort when it differs from the
        ledger round: the cohort-streaming executor masks each start
        *chunk* against itself (one cohort per chunk, several per
        round) so a chunk's masked sum cancels — and its payloads are
        freed — as soon as the whole chunk delivers, instead of only
        after the full fleet does.  ``collect`` / ``deliver`` /
        ``discard`` key by the same id (their ``start_rnd`` argument);
        the flat engines pass nothing and keep the one-cohort-per-round
        behavior bit-for-bit."""
        if not self.enabled:
            return
        cis = list(cohort)
        if not cis:
            return
        self._cohorts[rnd if cohort_id is None else cohort_id] = cis
        n = len(cis)
        if n < 2:
            return                         # nothing to mask against
        up = KEY_BYTES + (n - 1) * SHARE_BYTES
        down = (n - 1) * (KEY_BYTES + SHARE_BYTES)
        for ci in cis:
            ledger.record(rnd, ci, "secagg_keys", M.UP, up)
            ledger.record(rnd, ci, "secagg_keys", M.DOWN, down)

    def collect(self, start_rnd: int, ci: int, payload):
        """Stash client ``ci``'s upload (created in ``start_rnd``) as a
        fixed-point vector; masking is applied lazily at delivery."""
        if not self.enabled or start_rnd not in self._cohorts:
            return
        q = flat_fixed_point(payload, self.priv.secure_agg_frac_bits)
        self._plain[(start_rnd, ci)] = q
        self._size[start_rnd] = max(self._size.get(start_rnd, 0), len(q))

    # -- masks ------------------------------------------------------------- #
    def _pair_mask(self, start_rnd: int, i: int, j: int,
                   size: int) -> np.ndarray:
        lo, hi = (i, j) if i < j else (j, i)
        rng = np.random.default_rng(self._seed + (start_rnd, lo, hi))
        return rng.integers(0, np.iinfo(np.uint64).max, size=size,
                            dtype=np.uint64, endpoint=True)

    def _padded(self, start_rnd: int, ci: int) -> np.ndarray:
        q = self._plain[(start_rnd, ci)]
        size = self._size[start_rnd]
        if len(q) < size:
            q = np.concatenate([q, np.zeros(size - len(q), np.uint64)])
        return q

    def masked(self, start_rnd: int, ci: int) -> np.ndarray:
        """What client ``ci`` actually sends: payload + signed pairwise
        masks over its start cohort (mod 2^64)."""
        cohort = self._cohorts[start_rnd]
        size = self._size[start_rnd]
        out = self._padded(start_rnd, ci).copy()
        for cj in cohort:
            if cj == ci:
                continue
            m = self._pair_mask(start_rnd, ci, cj, size)
            out = out + m if ci < cj else out - m
        return out

    # -- aggregation events ------------------------------------------------ #
    def deliver(self, ledger: M.CommLedger, rnd: int,
                delivered: Iterable[Tuple[int, int]]):
        """One server aggregation event: ``delivered`` is the set of
        (start_round, client) uploads summed this round.  Verifies exact
        mask cancellation per start cohort (recovering the masks of
        absent members, with their recovery bytes charged) and forgets
        the consumed payloads."""
        if not self.enabled:
            return
        by_start: Dict[int, List[int]] = {}
        for start, ci in delivered:
            by_start.setdefault(start, []).append(ci)
        for start, cis in by_start.items():
            cohort = self._cohorts[start]
            size = self._size[start]
            present = set(cis)
            absent = [cj for cj in cohort if cj not in present]
            masked_sum = np.zeros(size, np.uint64)
            plain_sum = np.zeros(size, np.uint64)
            for ci in cis:
                masked_sum = masked_sum + self.masked(start, ci)
                plain_sum = plain_sum + self._padded(start, ci)
            # dropout recovery: reconstruct every (present, absent) mask
            # from the absent member's recovered seed shares
            residual = np.zeros(size, np.uint64)
            for ci in cis:
                for cj in absent:
                    m = self._pair_mask(start, ci, cj, size)
                    residual = residual + m if ci < cj else residual - m
            if absent:
                for ci in cis:
                    ledger.record(rnd, ci, "secagg_recovery", M.UP,
                                  SHARE_BYTES * len(absent))
            unmasked = masked_sum - residual
            if not np.array_equal(unmasked, plain_sum):
                raise AssertionError(
                    "secure-agg masks failed to cancel exactly "
                    f"(start={start}, delivered={sorted(present)}, "
                    f"cohort={cohort})")
            for ci in cis:
                del self._plain[(start, ci)]

    def discard(self, start_rnd: int, ci: int):
        """Server drops a too-stale masked upload without summing it
        (its pairwise masks are recovered by later events as usual)."""
        if self.enabled:
            self._plain.pop((start_rnd, ci), None)

    # -- checkpoint/resume (checkpoint/federated.py) ----------------------- #
    def state_dict(self) -> dict:
        """Mutable session state for crash recovery.  Keys are
        stringified for the JSON manifest; the fixed-point vectors stay
        raw uint64 arrays (bit-exact transport)."""
        return {
            "cohorts": {str(k): [int(x) for x in v]
                        for k, v in self._cohorts.items()},
            "size": {str(k): int(v) for k, v in self._size.items()},
            "plain": {f"{s}:{c}": q for (s, c), q in self._plain.items()},
        }

    def load_state_dict(self, st: dict):
        self._cohorts = {int(k): [int(x) for x in v]
                         for k, v in st["cohorts"].items()}
        self._size = {int(k): int(v) for k, v in st["size"].items()}
        self._plain = {}
        for key, q in st["plain"].items():
            s, c = key.split(":")
            # stays numpy: jnp.asarray would downcast uint64 without x64
            self._plain[(int(s), int(c))] = np.asarray(q, np.uint64)


def key_exchange_bytes(cohort_size: int) -> Tuple[int, int]:
    """(up, down) setup bytes per cohort member — the arithmetic twin
    of ``begin_cohort`` for dry-run records and docs."""
    n = cohort_size
    if n < 2:
        return 0, 0
    return (KEY_BYTES + (n - 1) * SHARE_BYTES,
            (n - 1) * (KEY_BYTES + SHARE_BYTES))
