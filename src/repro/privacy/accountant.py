"""RDP (moments) accountant for the Gaussian mechanism.

Every private release in this codebase is a full-participation Gaussian
mechanism: the client clips the sensitive quantity to L2 norm ``C``
(per-example gradients during local training; rows of the uploaded
logits/activations) and adds ``N(0, (sigma * C)^2)`` noise, so each
release is (alpha, alpha / (2 sigma^2))-RDP at every order alpha and
releases compose additively in RDP.  No subsampling amplification is
claimed: the engines run every client over its full local dataset each
round (sample rate q = 1), which is exactly the regime where the
RDP-of-Gaussian composition is tight.

Conversion to (eps, delta) uses the classic bound

    eps = min_alpha [ T * alpha / (2 sigma^2) + log(1/delta)/(alpha-1) ]

whose analytic optimum ``T/(2 sigma^2) + sqrt(2 T log(1/delta)) / sigma``
(attained at alpha* = 1 + sigma * sqrt(2 log(1/delta) / T)) is pinned by
the unit tests against the grid minimum.
"""
from __future__ import annotations

import math
from typing import Sequence

# Dense low orders (where the optimum lands for few steps / small
# sigma) plus a geometric tail for heavily-composed regimes.
DEFAULT_ORDERS: Sequence[float] = tuple(
    [1.0 + x / 10.0 for x in range(1, 100)]
    + list(range(11, 64))
    + [2 ** p for p in range(6, 10)])


def gaussian_rdp(order: float, noise_multiplier: float) -> float:
    """RDP of one Gaussian mechanism release at ``order`` (sigma in
    units of the clip norm): alpha / (2 sigma^2)."""
    if noise_multiplier <= 0.0:
        return math.inf
    return order / (2.0 * noise_multiplier ** 2)


def rdp_to_eps(rdp: float, order: float, delta: float) -> float:
    """Classic RDP -> (eps, delta) conversion at one order."""
    if order <= 1.0:
        return math.inf
    return rdp + math.log(1.0 / delta) / (order - 1.0)


class GaussianAccountant:
    """Tracks (eps, delta) of ``steps`` composed Gaussian releases."""

    def __init__(self, noise_multiplier: float, delta: float = 1e-5,
                 orders: Sequence[float] = DEFAULT_ORDERS):
        if delta <= 0.0 or delta >= 1.0:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.orders = tuple(orders)

    def epsilon(self, steps: int) -> float:
        """eps after ``steps`` releases (min over the order grid)."""
        if steps <= 0:
            return 0.0
        if self.noise_multiplier <= 0.0:
            return math.inf
        return min(
            rdp_to_eps(steps * gaussian_rdp(a, self.noise_multiplier),
                       a, self.delta)
            for a in self.orders)

    def closed_form_epsilon(self, steps: int) -> float:
        """The analytic optimum of the same bound (test oracle; the grid
        minimum approaches it from above)."""
        if steps <= 0:
            return 0.0
        s2 = self.noise_multiplier ** 2
        ln = math.log(1.0 / self.delta)
        return steps / (2.0 * s2) + math.sqrt(2.0 * steps * ln) \
            / self.noise_multiplier
