"""RDP (moments) accountant for the (subsampled) Gaussian mechanism.

Every private release in this codebase is a Gaussian mechanism: the
client clips the sensitive quantity to L2 norm ``C`` (per-example
gradients during local training; rows of the uploaded
logits/activations) and adds ``N(0, (sigma * C)^2)`` noise, so each
release is (alpha, alpha / (2 sigma^2))-RDP at every order alpha and
releases compose additively in RDP.

**Subsampling amplification** (``sample_rate`` q < 1): the engines
report the per-step sampling rate q = batch_size / |local data| (worst
case over clients), and each release is accounted as a *sampled
Gaussian mechanism* with the standard integer-order upper bound
(Mironov, Talwar & Zhang 2019, "Rényi Differential Privacy of the
Sampled Gaussian Mechanism"):

    RDP(alpha) <= 1/(alpha-1) * log( sum_{k=0}^{alpha}
        C(alpha, k) (1-q)^(alpha-k) q^k exp((k^2 - k) / (2 sigma^2)) )

computed in log-space (the exp terms overflow for large alpha
otherwise) and restricted to the integer orders of the grid.  At q = 1
the sum collapses to the k = alpha term and the bound reduces exactly
to alpha / (2 sigma^2) — the full-participation composition the q = 1
path uses at every (fractional) order, which is the regime where the
RDP-of-Gaussian composition is tight.

Two approximations to flag when reading the amplified figure: the
batching model is shuffled full passes rather than Poisson sampling,
and the FedLLM/KD noise sits at the *upload boundary* (one release per
round over a model that saw every local example) rather than per
subsampled step — only Split's per-step c2 activation noise matches
the sampled-release model exactly.  The reported epsilon is therefore
the standard optimistic DP-SGD-style figure; ROADMAP records
per-framework-exact accounting as the open next step.

Conversion to (eps, delta) uses the classic bound

    eps = min_alpha [ T * RDP(alpha) + log(1/delta)/(alpha-1) ]

whose q = 1 analytic optimum ``T/(2 sigma^2) + sqrt(2 T log(1/delta))
/ sigma`` (attained at alpha* = 1 + sigma * sqrt(2 log(1/delta) / T))
is pinned by the unit tests against the grid minimum; the q < 1 bound
is pinned against a literal re-computation of the MTZ sum.
"""
from __future__ import annotations

import math
from typing import Sequence

# Dense low orders (where the optimum lands for few steps / small
# sigma) plus a geometric tail for heavily-composed regimes.
DEFAULT_ORDERS: Sequence[float] = tuple(
    [1.0 + x / 10.0 for x in range(1, 100)]
    + list(range(11, 64))
    + [2 ** p for p in range(6, 10)])


def gaussian_rdp(order: float, noise_multiplier: float) -> float:
    """RDP of one full-participation Gaussian mechanism release at
    ``order`` (sigma in units of the clip norm): alpha / (2 sigma^2)."""
    if noise_multiplier <= 0.0:
        return math.inf
    return order / (2.0 * noise_multiplier ** 2)


def subsampled_gaussian_rdp(order: int, noise_multiplier: float,
                            sample_rate: float) -> float:
    """MTZ'19 integer-order upper bound on the RDP of one sampled
    Gaussian mechanism release (log-space; exact q=1 / q=0 limits)."""
    if noise_multiplier <= 0.0:
        return math.inf
    q = float(sample_rate)
    if q >= 1.0:
        return gaussian_rdp(order, noise_multiplier)
    if q <= 0.0:
        return 0.0
    a = int(order)
    if a < 2 or a != order:
        raise ValueError(
            f"the subsampled-Gaussian bound needs an integer order >= 2 "
            f"(got {order})")
    s2 = 2.0 * noise_multiplier ** 2
    logs = []
    for k in range(a + 1):
        log_binom = (math.lgamma(a + 1) - math.lgamma(k + 1)
                     - math.lgamma(a - k + 1))
        logs.append(log_binom + (a - k) * math.log1p(-q)
                    + (k * math.log(q) if k else 0.0)
                    + (k * k - k) / s2)
    m = max(logs)
    lse = m + math.log(sum(math.exp(x - m) for x in logs))
    return lse / (a - 1)


def rdp_to_eps(rdp: float, order: float, delta: float) -> float:
    """Classic RDP -> (eps, delta) conversion at one order."""
    if order <= 1.0:
        return math.inf
    return rdp + math.log(1.0 / delta) / (order - 1.0)


class GaussianAccountant:
    """Tracks (eps, delta) of ``steps`` composed (subsampled) Gaussian
    releases at sampling rate ``sample_rate`` (1.0 = every release
    covers the full local dataset — no amplification claimed)."""

    def __init__(self, noise_multiplier: float, delta: float = 1e-5,
                 orders: Sequence[float] = DEFAULT_ORDERS,
                 sample_rate: float = 1.0):
        if delta <= 0.0 or delta >= 1.0:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        if sample_rate <= 0.0 or sample_rate > 1.0:
            raise ValueError(
                f"sample_rate must be in (0, 1], got {sample_rate}")
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.orders = tuple(orders)
        self.sample_rate = float(sample_rate)
        if self.sample_rate < 1.0 and not any(
                float(a).is_integer() and a >= 2 for a in self.orders):
            raise ValueError(
                "sample_rate < 1 needs at least one integer order >= 2 "
                "in the grid (the subsampled-Gaussian bound only exists "
                f"there); got orders={self.orders}")

    def _usable_orders(self) -> Sequence[float]:
        """The subsampled bound only exists at integer orders >= 2; the
        full-participation path uses the whole (fractional) grid."""
        if self.sample_rate >= 1.0:
            return self.orders
        return tuple(a for a in self.orders
                     if float(a).is_integer() and a >= 2)

    def _rdp(self, order: float) -> float:
        if self.sample_rate >= 1.0:
            return gaussian_rdp(order, self.noise_multiplier)
        return subsampled_gaussian_rdp(int(order), self.noise_multiplier,
                                       self.sample_rate)

    def epsilon(self, steps: int) -> float:
        """eps after ``steps`` releases (min over the order grid)."""
        if steps <= 0:
            return 0.0
        if self.noise_multiplier <= 0.0:
            return math.inf
        return min(rdp_to_eps(steps * self._rdp(a), a, self.delta)
                   for a in self._usable_orders())

    def closed_form_epsilon(self, steps: int) -> float:
        """The analytic optimum of the q = 1 bound (test oracle; the
        grid minimum approaches it from above)."""
        if steps <= 0:
            return 0.0
        s2 = self.noise_multiplier ** 2
        ln = math.log(1.0 / self.delta)
        return steps / (2.0 * s2) + math.sqrt(2.0 * steps * ln) \
            / self.noise_multiplier
