"""Privacy subsystem: client-side DP-SGD, an RDP accountant, and
simulated secure aggregation — the paper's research-direction axes
(SSVI; FedLLM survey arXiv:2503.12016) as first-class scenario knobs.

Configured by ``configs/base.PrivacyConfig`` (``FedConfig.privacy``);
wired through every round engine (core/{rounds,rounds_spmd,async_agg})
uniformly over the three frameworks, both execution backends and both
aggregation schedules.  Per-framework threat surfaces:

==========  =========================  ================================
framework   private payload            mechanism
==========  =========================  ================================
FedLLM      LoRA param upload (a3)     per-example grad clip (DP-SGD)
                                       + Gaussian noise on the params
                                       + secure-agg masks on the upload
KD-FedLLM   public-set logits (b3)     per-example grad clip in b1 +
                                       row-clipped noisy logits (before
                                       top-k/int-quant compression) +
                                       secure-agg masks on the upload
Split       smashed activations (c2)   per-token-row clip + Gaussian
            + client-half LoRA (cc1)   noise on every boundary
                                       transfer; secure-agg masks on
                                       the adapter upload
==========  =========================  ================================
"""
from repro.privacy.accountant import GaussianAccountant  # noqa: F401
from repro.privacy.dp import (clipped_grad_mean, noise_key,  # noqa: F401
                              privatize_logits, privatize_rows,
                              privatize_tree)
from repro.privacy.secure_agg import SecureAggSession  # noqa: F401
