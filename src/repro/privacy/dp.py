"""Client-side DP-SGD primitives (``PrivacyConfig.dp_clip`` /
``dp_noise_multiplier``).

Two mechanisms compose:

- **Per-example gradient clipping** inside every local fine-tune step:
  the step computes stacked per-example LoRA gradients, flattens them to
  one (B, P) matrix and runs the fused clip-scale-accumulate kernel
  (kernels/ops.clip_mean_rows — Pallas under the ``pallas`` policy, the
  XLA reference otherwise).  Deterministic, so backend parity is free.

- **Seeded Gaussian noise on the uploaded payload**: params (FedLLM),
  row-clipped logits (KD b3, before compression) or the smashed
  boundary activations (Split c2).  Noise keys derive from a dedicated
  ``fold_in`` stream over (privacy seed, round, client[, step]) —
  *never* the dropout RNG — so the sequential and SPMD backends draw
  bit-identical noise (tests/test_privacy.py pins this).

The noise scale is ``sigma * C`` (PrivacyConfig.noise_std): each round's
upload is accounted as one Gaussian-mechanism release of a C-clipped
quantity (privacy/accountant.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.rng import fold_chain

_STREAM = 0x5EC7  # domain separator: privacy noise vs fed/dropout seeds


def _run_key(fed: FedConfig):
    """Root of the privacy noise stream: (fed.seed, privacy.seed) each
    folded in separately, so distinct config pairs can never collide."""
    return fold_chain(jax.random.PRNGKey(fed.seed), _STREAM,
                      fed.privacy.seed)


def noise_key(fed: FedConfig, rnd: int, ci: int, step: int = 0):
    """Per-(round, client[, step]) noise key — identical on every
    execution backend by construction (core/rng.fold_chain)."""
    return fold_chain(_run_key(fed), rnd, ci, step)


def noise_key_grid(fed: FedConfig, rnd: int, cis, n_steps: int):
    """(|cis|, n_steps) stacked noise keys for the SPMD scan bodies —
    row k, column s is exactly ``noise_key(fed, rnd, cis[k], s)``
    (vmapped fold_in: a handful of dispatches, not C*S)."""
    base = jax.random.fold_in(_run_key(fed), rnd)
    steps = jnp.arange(n_steps)

    def row(ci):
        k = jax.random.fold_in(base, ci)
        return jax.vmap(lambda s: jax.random.fold_in(k, s))(steps)

    return jax.vmap(row)(jnp.asarray(list(cis)))


# --------------------------------------------------------------------------- #
# Per-example clipping (the DP-SGD step body)
# --------------------------------------------------------------------------- #
def clipped_grad_mean(per_example_grads, clip: float):
    """Stacked per-example grad tree (leaves (B, ...)) -> mean tree of
    the per-example L2-clipped gradients, through the fused kernel."""
    from repro.kernels import ops as kernel_ops

    leaves, treedef = jax.tree.flatten(per_example_grads)
    B = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(B, -1).astype(jnp.float32) for x in leaves], axis=1)
    mean = kernel_ops.clip_mean_rows(flat, clip)            # (P,) fp32
    out, off = [], 0
    for x in leaves:
        n = x[0].size
        out.append(mean[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------- #
# Payload noise (upload boundary)
# --------------------------------------------------------------------------- #
def privatize_tree(tree, key, std: float):
    """tree + iid N(0, std^2) per leaf (fp32 draw, cast to leaf dtype).
    ``std == 0`` is the identity — no program or bit changes."""
    if std <= 0.0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    out = [x + (jax.random.normal(jax.random.fold_in(key, i), x.shape,
                                  jnp.float32) * std).astype(x.dtype)
           for i, x in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def clip_rows(x, clip: float):
    """Clip each row (last-axis vector) of ``x`` to L2 norm ``clip``
    (optim/clip's fp32 eps-guarded scale — one formula everywhere)."""
    from repro.optim.clip import _clip_scale
    x32 = x.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(x32 * x32, axis=-1, keepdims=True))
    return (x32 * _clip_scale(norms, clip)).astype(x.dtype)


def privatize_rows(x, key, fed: FedConfig):
    """Row-clip + Gaussian-noise a (..., d) tensor — the Split boundary
    activation mechanism (c2) and the building block of
    ``privatize_logits``.  Identity when DP is off."""
    priv = fed.privacy
    if not priv.dp_enabled:
        return x
    y = clip_rows(x, priv.dp_clip)
    if priv.noise_std > 0.0:
        y = y + (jax.random.normal(key, y.shape, jnp.float32)
                 * priv.noise_std).astype(y.dtype)
    return y


def privatize_logits(logits, key, fed: FedConfig):
    """KD b3 upload mechanism: per-row clipped, noised logits — applied
    *before* the top-k/int-quant compression so the two SSIV.B.2 wire
    features compose with privacy."""
    return privatize_rows(logits, key, fed)
