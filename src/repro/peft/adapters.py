"""Bottleneck adapters (Houlsby-style) — the paper's second PEFT option.

``attach`` returns an *adapter tree* shaped like the model's block
stacks; models/transformer.block_fwd applies ``x + W_up·gelu(W_down·x)``
after the MLP residual whenever a block's params carry an "adapter" key
(bound via ``bind``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init_adapters(key, base_params, d_model: int, bottleneck: int = 64,
                  dtype=jnp.float32):
    """One adapter per block (stacked over groups like the base tree)."""

    def make(shape_src, k):
        g = shape_src.shape[0] if shape_src.ndim == 3 else None
        k1, k2 = jax.random.split(k)
        shape_d = (g, d_model, bottleneck) if g else (d_model, bottleneck)
        shape_u = (g, bottleneck, d_model) if g else (bottleneck, d_model)
        return {"w_down": common.dense_init(k1, shape_d, dtype),
                "w_up": jnp.zeros(shape_u, dtype)}     # zero-init: identity

    out = {"blocks": [], "tail": []}
    for i, blk in enumerate(base_params["blocks"]):
        ref = blk["norm1"]["scale"]                    # (G, d)
        out["blocks"].append(make(ref[..., None], jax.random.fold_in(key, i)))
    for i, blk in enumerate(base_params["tail"]):
        ref = blk["norm1"]["scale"][..., None]
        out["tail"].append(make(ref, jax.random.fold_in(key, 1000 + i)))
    out["blocks"] = tuple(out["blocks"])
    out["tail"] = tuple(out["tail"])
    return out


def bind(base_params, adapter_tree):
    """Insert adapter params into each block subtree."""
    out = dict(base_params)
    blocks = []
    for blk, ad in zip(base_params["blocks"], adapter_tree["blocks"]):
        b = dict(blk)
        b["adapter"] = ad
        blocks.append(b)
    out["blocks"] = tuple(blocks)
    tail = []
    for blk, ad in zip(base_params["tail"], adapter_tree["tail"]):
        b = dict(blk)
        b["adapter"] = ad
        tail.append(b)
    out["tail"] = tuple(tail)
    return out


def adapter_fwd(p, x):
    h = common.gelu(common.mm(x, p["w_down"]))
    return x + common.mm(h, p["w_up"])
