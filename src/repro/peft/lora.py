"""LoRA (Low-Rank Adaptation) over functional param trees.

A LoRA tree mirrors the base tree at *targeted* leaves only:

    base:  {"blocks": ({"attn": {"wq": (G,d,f), ...}, ...},)}
    lora:  {"blocks": ({"attn": {"wq": {"a": (G,d,r), "b": (G,r,f)}},},)}

``bind`` produces the tree the model consumes, replacing each targeted
weight W with ``{"w": W, "a": A, "b": B, "s": alpha/r}`` — models/common.mm
dispatches on that dict, computing ``x@W + (x@A)@B*s`` without ever
materializing W + BA (the Pallas ``lora_matmul`` kernel fuses the same
computation on TPU).

Gradient flow: core/fedavg closes over the *base* tree and differentiates
w.r.t. the LoRA tree only, so the base stays frozen with zero optimizer
state — the PEFT property all three paper frameworks rely on.

Paper (SSV) targets GPT-2's fused ``attn.c_attn``; with split projections
the equivalent target set is ("wq","wk","wv").
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# weight names LoRA may target, per module kind
DEFAULT_TARGETS: Tuple[str, ...] = ("wq", "wk", "wv")
RWKV_TARGETS: Tuple[str, ...] = ("w_r", "w_k", "w_v", "w_g")


def lora_apply(x: jax.Array, w: jax.Array, a: jax.Array,
               b: jax.Array) -> jax.Array:
    """The LoRA projection hot path: ``x@W + (x@A)@B`` (scale folded into
    ``b`` at bind time).

    Under kernel policy ``pallas`` (kernels/ops.policy_scope) this runs
    the fused Pallas kernel — one HBM pass over W with the rank-r panel
    VMEM-resident, differentiable via its custom_vjp backward kernels.
    Otherwise the XLA einsum chain (never materializing W + BA)."""
    from repro.kernels import ops as kernel_ops
    if kernel_ops.use_pallas() and w.ndim == 2:
        return kernel_ops.lora_matmul(x, w, a, b)
    base = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    lo = jnp.einsum("...d,dr->...r", x, a.astype(x.dtype))
    lo = jnp.einsum("...r,rf->...f", lo, b.astype(x.dtype))
    return base + lo


def default_targets(cfg) -> Tuple[str, ...]:
    """Paper-faithful targets, adapted per family (DESIGN SSArch-applicability):
    attention archs -> QKV; attention-free RWKV -> time-mix projections."""
    if cfg.attention_free:
        return RWKV_TARGETS
    return DEFAULT_TARGETS


def _walk(tree, fn: Callable, path: Tuple[str, ...] = ()):
    """Depth-first walk; fn(path, leaf) -> replacement or None (drop)."""
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            r = _walk(v, fn, path + (str(k),))
            if r is not None:
                out[k] = r
        return out or None
    if isinstance(tree, (tuple, list)):
        out = []
        keep = False
        for i, v in enumerate(tree):
            r = _walk(v, fn, path + (str(i),))
            keep = keep or (r is not None)
            out.append(r)
        return tuple(out) if keep else None
    return fn(path, tree)


def init_lora(key, base_params, targets: Sequence[str], rank: int,
              alpha: float = 32.0, dtype=jnp.float32):
    """Build a LoRA tree.  A ~ N(0, 1/r) (paper: Gaussian init), B = 0."""
    counter = [0]

    def init_leaf(path, leaf):
        if path[-1] not in targets or not hasattr(leaf, "ndim"):
            return None
        if leaf.ndim < 2:
            return None
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        *batch_dims, d_in, d_out = leaf.shape
        a = jax.random.normal(k, (*batch_dims, d_in, rank),
                              jnp.float32) * (rank ** -0.5)
        b = jnp.zeros((*batch_dims, rank, d_out), jnp.float32)
        return {"a": a.astype(dtype), "b": b.astype(dtype)}

    lora = _walk(base_params, init_leaf)
    return lora if lora is not None else {}


def bind(base_params, lora_tree, alpha: float, rank: int,
         dropout_mask_rng: Optional[jax.Array] = None,
         dropout: float = 0.0):
    """Return the model-consumable tree with LoRA leaves bound.

    ``dropout`` drops input features on the LoRA branch only (per-call
    feature mask — the pure-functional form of LoRA dropout)."""
    scale = alpha / max(rank, 1)
    counter = [0]

    def combine(b, l):
        if isinstance(l, dict) and set(l) == {"a", "b"} and hasattr(
                l["a"], "ndim"):
            a = l["a"]
            if dropout > 0.0 and dropout_mask_rng is not None:
                # fold feature-dropout mask into A: (x*m)@A == x@(m[:,None]*A)
                counter[0] += 1
                k = jax.random.fold_in(dropout_mask_rng, counter[0])
                d_in = a.shape[-2]
                keep = jax.random.bernoulli(k, 1.0 - dropout, (d_in,))
                a = a * (keep.astype(a.dtype) / (1.0 - dropout))[:, None]
            # fold alpha/r into B so bound leaves stay plain arrays
            return {"w": b, "a": a, "b": l["b"] * scale}
        if isinstance(b, dict):
            return {k: combine(b[k], l[k]) if (isinstance(l, dict)
                                               and k in l) else b[k]
                    for k in b}
        if isinstance(b, (tuple, list)):
            return tuple(
                combine(bv, l[i]) if (isinstance(l, (tuple, list))
                                      and l[i] is not None) else bv
                for i, bv in enumerate(b))
        return b

    return combine(base_params, lora_tree)


def merge(base_params, lora_tree, alpha: float, rank: int):
    """Materialize W + s*A@B (serving path; inverse of bind)."""
    scale = alpha / max(rank, 1)

    def combine(b, l):
        if isinstance(l, dict) and set(l) == {"a", "b"} and hasattr(
                l["a"], "ndim"):
            delta = jnp.einsum("...dr,...rf->...df", l["a"], l["b"]) * scale
            return (b + delta.astype(b.dtype))
        if isinstance(b, dict):
            return {k: combine(b[k], l[k]) if (isinstance(l, dict)
                                               and k in l) else b[k]
                    for k in b}
        if isinstance(b, (tuple, list)):
            return tuple(
                combine(bv, l[i]) if (isinstance(l, (tuple, list))
                                      and l[i] is not None) else bv
                for i, bv in enumerate(b))
        return b

    return combine(base_params, lora_tree)


def tree_rank(lora_tree, default: int) -> int:
    """Infer a LoRA tree's rank from its leading ``a`` factor's last dim
    — binding reads the rank off the tree itself, so truncated /
    heterogeneous-rank trees always get the matching alpha/r scale."""
    for leaf in jax.tree.leaves(lora_tree):
        if leaf.ndim >= 2:
            return leaf.shape[-1] if leaf.shape[-1] != 0 else default
    return default


def n_params(lora_tree) -> int:
    return sum(x.size for x in jax.tree.leaves(lora_tree))


def n_bytes(lora_tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(lora_tree))


# --------------------------------------------------------------------------- #
# Heterogeneous-rank harmonization (paper SS IV.A.2 — beyond-paper feature)
# --------------------------------------------------------------------------- #
def pad_rank(lora_tree, target_rank: int, rescale: bool = True):
    """Zero-pad a LoRA tree's rank dim up to ``target_rank``.

    bind() scales the delta by alpha/rank, so growing the rank would
    silently shrink the learned delta; with ``rescale`` (default) B is
    multiplied by target/orig so the effective delta is preserved exactly
    (padded rows of B are zero, so the extra rank starts inert)."""

    def pad(x, axis):
        pad_n = target_rank - x.shape[axis]
        if pad_n <= 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad_n)
        return jnp.pad(x, widths)

    def rec(l):
        if isinstance(l, dict) and set(l) == {"a", "b"}:
            orig = l["a"].shape[-1]
            gain = (target_rank / orig) if (rescale and orig) else 1.0
            return {"a": pad(l["a"], -1), "b": pad(l["b"] * gain, -2)}
        if isinstance(l, dict):
            return {k: rec(v) for k, v in l.items()}
        if isinstance(l, (tuple, list)):
            return tuple(rec(v) if v is not None else None for v in l)
        return l

    return rec(lora_tree)


def truncate_rank(lora_tree, rank: int, orig_rank: int):
    """Keep the first ``rank`` components, rescaling for bind's alpha/r:
    the client binds with alpha/rank, the global delta was alpha/orig, so
    B shrinks by rank/orig to keep the effective delta scale."""
    gain = rank / max(orig_rank, 1)

    def rec(l):
        if isinstance(l, dict) and set(l) == {"a", "b"}:
            return {"a": l["a"][..., :rank],
                    "b": l["b"][..., :rank, :] * gain}
        if isinstance(l, dict):
            return {k: rec(v) for k, v in l.items()}
        if isinstance(l, (tuple, list)):
            return tuple(rec(v) if v is not None else None for v in l)
        return l

    return rec(lora_tree)


def maybe_truncate_rank(lora_tree, rank: int, orig_rank: int):
    """The a1/cc3 distribution rule: weak clients get a truncated copy
    of the global tree, full-rank clients the tree itself."""
    if rank == orig_rank:
        return lora_tree
    return truncate_rank(lora_tree, rank, orig_rank)


def svd_truncate(delta: jax.Array, rank: int):
    """Rank-r factorization of a (possibly stacked) delta via SVD."""
    u, s, vt = jnp.linalg.svd(delta.astype(jnp.float32), full_matrices=False)
    u = u[..., :, :rank] * s[..., None, :rank]
    return u, vt[..., :rank, :]
