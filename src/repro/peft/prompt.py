"""Prompt tuning — the paper's third PEFT option: ``n_virtual`` learned
embeddings prepended to every input sequence (soft prompt)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_prompt(key, d_model: int, n_virtual: int = 16, dtype=jnp.float32):
    return {"prompt": jax.random.normal(key, (n_virtual, d_model),
                                        jnp.float32).astype(dtype) * 0.02}


def expand(prompt_tree, batch: int):
    """(n_virtual, d) -> (B, n_virtual, d) prefix embeddings."""
    p = prompt_tree["prompt"]
    return jnp.broadcast_to(p[None], (batch,) + p.shape)
