"""Optimizer facade used by the federated round engine and launcher."""
from __future__ import annotations

from repro.optim import adam, sgd


def make_optimizer(name: str, **kw):
    """Returns (init_fn(params) -> state,
                update_fn(grads, state, params, lr) -> (params, state))."""
    if name == "adam":
        def upd(g, s, p, lr):
            return adam.update(g, s, p, lr,
                               weight_decay=kw.get("weight_decay", 0.0))
        return adam.init, upd
    if name == "sgd":
        mom = kw.get("momentum", 0.0)
        return (lambda p: sgd.init(p, mom),
                lambda g, s, p, lr: sgd.update(g, s, p, lr, mom))
    raise ValueError(name)
