"""Gradient utilities: global-norm clip, finite-check."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def all_finite(tree) -> jax.Array:
    ok = [jnp.all(jnp.isfinite(x.astype(jnp.float32)))
          for x in jax.tree.leaves(tree)]
    return jnp.stack(ok).all() if ok else jnp.asarray(True)
