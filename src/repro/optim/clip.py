"""Gradient utilities: global-norm clip (whole-tree and stacked
per-example variants), finite-check.

All norm/scale arithmetic runs in float32 regardless of leaf dtype —
under bf16 trees the old ``max_norm / (norm + 1e-9)`` guard could see
its epsilon rounded away (bf16 has ~8 significand bits) and the ratio
computed at leaf precision; the guard here is an explicit fp32
``maximum(norm, eps)`` so the scale is exact and finite for any leaf
dtype, including an all-zero tree.

The per-example variants treat leading axis 0 of every leaf as the
example axis — the shape DP-SGD's stacked per-example LoRA gradient
trees arrive in (privacy/dp.py; the fused Pallas clip-scale-accumulate
kernel in kernels/dp_clip.py is the hot-path twin of this reference).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-9


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def _clip_scale(norm, max_norm: float) -> jax.Array:
    """fp32 scale ``min(1, C / max(norm, eps))`` — dtype-safe for any
    leaf dtype (the epsilon guard never touches sub-fp32 precision)."""
    norm32 = jnp.asarray(norm, jnp.float32)
    return jnp.minimum(jnp.float32(1.0),
                       jnp.float32(max_norm) / jnp.maximum(norm32, EPS))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = _clip_scale(norm, max_norm)
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def per_example_global_norm(tree) -> jax.Array:
    """(B,) global norms of a stacked per-example tree: every leaf has
    example axis 0; the norm of example ``b`` spans all leaves' ``[b]``
    slices.  fp32 accumulation independent of leaf dtype."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32)).reshape(x.shape[0], -1),
                  axis=1) for x in leaves]
    return jnp.sqrt(sum(sq))


def clip_per_example(tree, max_norm: float):
    """Clip every example slice of a stacked tree to ``max_norm``.

    Returns ``(clipped_tree, norms)`` where ``norms`` is the (B,) vector
    of pre-clip global norms.  Leaf dtypes are preserved; scales are
    fp32 (dtype-safe under bf16 trees)."""
    norms = per_example_global_norm(tree)
    scale = _clip_scale(norms, max_norm)                    # (B,)

    def clip_leaf(x):
        s = scale.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * s).astype(x.dtype)

    return jax.tree.map(clip_leaf, tree), norms


def all_finite(tree) -> jax.Array:
    ok = [jnp.all(jnp.isfinite(x.astype(jnp.float32)))
          for x in jax.tree.leaves(tree)]
    return jnp.stack(ok).all() if ok else jnp.asarray(True)
