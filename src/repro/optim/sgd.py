"""SGD with optional momentum."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, momentum: float = 0.0):
    if momentum:
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    return {"mu": None}


def update(grads, state, params, lr, momentum: float = 0.0):
    if momentum and state["mu"] is not None:
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_p, {"mu": mu}
    new_p = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_p, state
