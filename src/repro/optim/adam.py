"""Adam / AdamW over arbitrary pytrees (built from scratch; no optax in
this environment).  fp32 moments regardless of param dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def update(grads, state, params, lr, b1: float = 0.9, b2: float = 0.999,
           eps: float = 1e-8, weight_decay: float = 0.0):
    """Returns (new_params, new_state)."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    return (treedef.unflatten(new_p),
            {"m": treedef.unflatten(new_m),
             "v": treedef.unflatten(new_v),
             "step": step})
