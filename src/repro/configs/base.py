"""Configuration dataclasses for the repro framework.

``ModelConfig`` is a single schema that covers every assigned architecture
family (dense / moe / hybrid / ssm / vlm / audio).  Architectures are
expressed as a *layer-type sequence* plus per-layer MLP kind, so one
functional transformer core (models/transformer.py) serves all of them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Layer kinds understood by models/transformer.py
ATTN = "attn"              # global causal self-attention
LOCAL_ATTN = "local_attn"  # sliding-window self-attention
RGLRU = "rglru"            # RG-LRU recurrent block (RecurrentGemma)
RWKV6 = "rwkv6"            # RWKV-6 "Finch" time-mix block

FAMILIES = ("dense", "moe", "hybrid", "ssm", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.  One instance per assigned arch."""

    name: str
    family: str                       # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                      # query heads (0 for attn-free archs)
    n_kv_heads: int                   # GQA KV heads
    d_ff: int
    vocab_size: int

    # -- attention details ----------------------------------------------
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False            # qwen2-style QKV bias
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q,k
    sliding_window: int = 0           # 0 -> global attention (mixtral: 4096)
    rope_theta: float = 10_000.0
    use_rope: bool = True             # False -> learned absolute positions
    max_position_embeddings: int = 1_048_576

    # -- MLP / MoE --------------------------------------------------------
    activation: str = "swiglu"        # swiglu | gelu | relu2
    n_experts: int = 0                # 0 -> dense MLP
    top_k: int = 0
    router_aux_coef: float = 0.01     # load-balance loss coefficient
    moe_capacity_factor: float = 1.25  # train-time token-drop threshold
    moe_dispatch: str = "global"      # global | batched (SSPerf hillclimb)

    # -- layer pattern ----------------------------------------------------
    # None -> homogeneous (all `attn`).  RecurrentGemma: ("rglru","rglru",
    # "local_attn") repeated; rwkv6: all "rwkv6".
    layer_pattern: Optional[Tuple[str, ...]] = None

    # -- recurrent-family extras -----------------------------------------
    lru_width: int = 0                # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4             # RecurrentGemma temporal-conv width
    local_window: int = 2048          # window for LOCAL_ATTN layers

    # -- norms / embeddings ----------------------------------------------
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma-style sqrt(d_model) scaling

    # -- encoder-decoder (whisper) ----------------------------------------
    n_encoder_layers: int = 0         # >0 -> encoder-decoder model
    encoder_seq_len: int = 1500       # whisper 30s -> 1500 frames

    # -- multimodal (llava) ------------------------------------------------
    n_image_tokens: int = 0           # >0 -> embedding-prefix VLM
    image_embed_dim: int = 0          # projector input dim (stubbed frontend)

    dtype: str = "bfloat16"
    citation: str = ""

    # -- kernel dispatch ---------------------------------------------------
    # Which implementation the hot paths (LoRA projection, attention, KD
    # loss) trace through:  ``xla`` — reference jnp paths;  ``pallas`` —
    # the fused differentiable Pallas kernels (kernels/ops.py);  ``auto``
    # — pallas on a real TPU backend, xla elsewhere (interpret-mode
    # Pallas is a correctness tool, not a fast path).
    kernel_policy: str = "auto"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.kernel_policy not in ("xla", "pallas", "auto"):
            raise ValueError(
                f"unknown kernel_policy {self.kernel_policy!r} "
                "(expected 'xla' | 'pallas' | 'auto')")
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------ #
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind sequence of length n_layers."""
        if self.layer_pattern is None:
            return (ATTN,) * self.n_layers
        pat = self.layer_pattern
        reps = -(-self.n_layers // len(pat))
        return (pat * reps)[: self.n_layers]

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(k in (RGLRU, RWKV6) for k in self.layer_kinds)

    @property
    def subquadratic(self) -> bool:
        """True when decode state does not grow with context length."""
        return all(
            k in (RGLRU, RWKV6, LOCAL_ATTN) for k in self.layer_kinds
        ) or (self.sliding_window > 0)

    # -- parameter counting (analytic; used by roofline + fed metrics) ---
    def param_count(self) -> int:
        return sum(x for x, _ in self._param_terms())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        return sum(a for _, a in self._param_terms())

    def _param_terms(self):
        """Yields (total, active) parameter-count pairs per component."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        yield V * d, V * d                                   # embedding
        if not self.tie_embeddings:
            yield V * d, V * d                               # lm head
        for kind in self.layer_kinds:
            if kind in (ATTN, LOCAL_ATTN):
                q = d * self.n_heads * self.head_dim
                kv = 2 * d * self.n_kv_heads * self.head_dim
                o = self.n_heads * self.head_dim * d
                yield q + kv + o, q + kv + o
            elif kind == RGLRU:
                w = self.lru_width
                # in/out proj (2 branches) + conv1d + gates + out
                n = 2 * d * w + self.conv1d_width * w + 3 * w + w * d
                yield n, n
            elif kind == RWKV6:
                # r,k,v,g,o projections + decay lora + token-shift mixes
                n = 5 * d * d + 2 * d * 64 + 6 * d
                yield n, n
            # MLP
            if self.n_experts and kind != RWKV6:
                mult = 3 if self.activation == "swiglu" else 2
                per_e = mult * d * ff
                yield (self.n_experts * per_e + d * self.n_experts,
                       self.top_k * per_e + d * self.n_experts)
            else:
                mult = 3 if self.activation == "swiglu" else 2
                yield mult * d * ff, mult * d * ff
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp + cross-attn params in decoder
            # (decoder cross-attn counted here for simplicity)
            enc = self.n_encoder_layers * (
                4 * d * d + 2 * d * ff)
            xattn = self.n_layers * 4 * d * d
            yield enc + xattn, enc + xattn

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                n_experts: int = 4) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (charter: <=2
        layers, d_model<=512, <=4 experts)."""
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if n_heads else 0
        pat = self.layer_pattern
        if pat is not None:
            n_layers = max(n_layers, len(pat))   # keep one full pattern
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=max(1, n_kv),
            head_dim=d_model // n_heads if n_heads else 0,
            d_ff=d_model * 3,
            vocab_size=512,
            n_experts=min(self.n_experts, n_experts) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            lru_width=d_model,
            local_window=64,
            sliding_window=64 if self.sliding_window else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq_len=16 if self.n_encoder_layers else 1500,
            n_image_tokens=8 if self.n_image_tokens else 0,
            image_embed_dim=64 if self.image_embed_dim else 0,
            max_position_embeddings=4096,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """Privacy mechanisms for the federated wire (src/repro/privacy/).

    DP-SGD (``dp_clip`` / ``dp_noise_multiplier``): per-example gradient
    clipping inside every local fine-tune step (FedLLM a2, KD b1) plus
    seeded Gaussian noise on the uploaded payload — LoRA params for
    FedLLM, public-set logits for KD (clipped per row, composing with
    the top-k/int-quant compression), and the smashed boundary
    activations for Split (clipped per token row, noised per transfer).
    Noise keys are per-(client, round[, step]) ``fold_in`` streams, so
    both execution backends draw bit-identical noise.  An RDP accountant
    (privacy/accountant.py) reports (ε, δ) per round in RoundMetrics.

    Simulated secure aggregation (``secure_agg``): seeded pairwise
    additive masks over fixed-point payloads that cancel *exactly* in
    the server sum (privacy/secure_agg.py verifies the cancellation in
    uint64 arithmetic every aggregation event); key/mask-exchange and
    dropout-recovery bytes are recorded in the CommLedger so Fig. 4
    wire accounting includes the cost of privacy."""

    dp_clip: float = 0.0             # C: per-example L2 clip (0 = DP off)
    dp_noise_multiplier: float = 0.0  # sigma: noise stddev / dp_clip
    dp_delta: float = 1e-5           # delta of the reported (eps, delta)
    secure_agg: bool = False         # pairwise-masked aggregation overlay
    secure_agg_frac_bits: int = 24   # fixed-point fraction bits for masks
    seed: int = 0                    # privacy noise stream (folded in
    #                                  alongside FedConfig.seed — see
    #                                  privacy/dp._run_key; independent
    #                                  of the dropout/batching streams)

    @property
    def dp_enabled(self) -> bool:
        return self.dp_clip > 0.0

    @property
    def noise_std(self) -> float:
        """Gaussian stddev of the payload noise (sigma * C)."""
        return self.dp_noise_multiplier * self.dp_clip

    @property
    def enabled(self) -> bool:
        return self.dp_enabled or self.secure_agg


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault injection for the round engine (src/repro/faults/).

    A ``FaultPlan`` derives every fault decision from
    ``(FedConfig.seed, FaultConfig.seed, round, client)`` fold-in
    streams (core/rng.host_fold_rng), so a faulted run is exactly
    reproducible on any framework x backend x schedule combo and is
    independent of the batching / dropout / privacy RNG streams.

    Fault taxonomy:
      * dropout   — the client trains but its upload is lost in transit
                    (charged as ``retransmit`` bytes in the CommLedger;
                    under secure aggregation the cohort's survivors pay
                    the usual mask-recovery traffic)
      * straggler — the upload arrives ``straggler_delay`` rounds late,
                    flowing through the staleness-weighted async path
      * byzantine — ``byzantine`` clients (a seeded fixed subset of the
                    population) corrupt every payload they upload:
                    ``nan`` / ``inf`` (caught by the finite-check
                    validator and quarantined), ``sign_flip`` (negated
                    update), or ``norm_inflation`` (scaled by
                    ``byzantine_scale``; caught by the norm screen or
                    absorbed by a robust aggregator)
    """

    dropout_rate: float = 0.0        # P(upload lost) per started job
    straggler_rate: float = 0.0      # P(upload delayed) per started job
    straggler_delay: int = 2         # extra rounds a straggling upload takes
    byzantine: int = 0               # number of permanently corrupt clients
    byzantine_mode: str = "sign_flip"  # nan | inf | sign_flip | norm_inflation
    byzantine_scale: float = 100.0   # multiplier for norm_inflation
    seed: int = 0                    # fault stream (folded with FedConfig.seed)

    @property
    def enabled(self) -> bool:
        return (self.dropout_rate > 0.0 or self.straggler_rate > 0.0
                or self.byzantine > 0)


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Federated fine-tuning round configuration (paper SS II/V)."""
    framework: str = "fedllm"        # fedllm | kd | split
    # Execution backend for the round engine (core/rounds.py):
    #   sequential — python loop over clients, one jitted step per batch
    #   spmd       — clients stacked on a leading axis, one jitted
    #                program per round (core/fed_spmd.py); client axis
    #                shardable over a multi-pod mesh's ``pod`` dim
    #   cohort     — cohort-streaming: the round's clients stream
    #                through the SPMD stage programs ``cohort_size`` at
    #                a time with jitted partial-aggregate folds between
    #                chunks, so peak memory is one cohort (the
    #                million-virtual-client path)
    backend: str = "sequential"      # sequential | spmd | cohort
    n_clients: int = 3
    # cohort-streaming knobs (backend="cohort"; core/round_program.py):
    #   cohort_size        — clients materialized/stacked per chunk
    #                        (0 = the whole ready set in one chunk)
    #   n_virtual_clients  — declared fleet size when clients come from
    #                        a lazy ClientPopulation (0 = len(clients));
    #                        validated against the supplied population
    #   n_edges            — edge aggregators of the two-hop hierarchy
    #                        (client -> edge -> server); 0 derives the
    #                        count from the mesh (one edge per pod),
    #                        1 = flat single-hop accounting
    cohort_size: int = 0
    n_virtual_clients: int = 0
    n_edges: int = 0
    rounds: int = 10
    local_epochs: int = 1
    # PEFT
    peft: str = "lora"               # lora | adapter | prompt | full
    lora_rank: int = 8
    lora_alpha: float = 32.0
    lora_dropout: float = 0.1
    lora_targets: Tuple[str, ...] = ("wq", "wk", "wv")  # paper: attn.c_attn
    # KD-FedLLM
    public_dataset_size: int = 512
    kd_temperature: float = 2.0
    kd_epochs: int = 1
    logit_topk: int = 0              # 0 = dense logits (paper baseline)
    logit_quant_bits: int = 0        # 0 = fp32 logits
    # Split-FedLLM
    split_layer: int = 1             # client keeps layers [0, split_layer)
    split_mode: str = "inter"        # inter | intra
    activation_quant_bits: int = 0   # 0 = bf16/fp32 activations
    # heterogeneous clients (SS IV.A.2)
    client_ranks: Optional[Tuple[int, ...]] = None
    hetero_agg: str = "zeropad"      # zeropad | svd
    # aggregation schedule (core/async_agg.py):
    #   sync  — every client delivers its update in the round it trains
    #           (the paper-literal parameter-server round)
    #   async — a seeded per-client delay model decides when each update
    #           arrives; the server folds arrivals in with polynomial
    #           staleness-decay weights (FedAsync-style)
    aggregation: str = "sync"        # sync | async
    staleness_decay: float = 0.5     # weight = (1 + staleness)^-decay
    max_staleness: int = 4           # drop updates staler than this;
    #                                  0 = force synchronous participation
    # privacy subsystem (src/repro/privacy/): client-side DP-SGD and
    # simulated secure aggregation, uniform over frameworks/backends
    privacy: PrivacyConfig = dataclasses.field(default_factory=PrivacyConfig)
    # fault tolerance (src/repro/faults/ + core/round_program.py):
    #   faults        — seeded dropout/straggler/byzantine injection plan
    #   robust_agg    — server-side combine over the stacked client axis:
    #                   mean (paper-literal weighted mean) | median
    #                   (coordinate-wise) | trimmed_mean (drop the
    #                   ``trim_frac`` extremes per coordinate) |
    #                   norm_clip (clip each update's L2 norm to
    #                   ``clip_norm`` — 0 = the cohort's median norm —
    #                   before the weighted mean)
    #   quorum        — min fraction of the round's started clients that
    #                   must survive validation/staleness for the round
    #                   to aggregate; below it the round rolls over
    #                   deterministically (global state unchanged)
    #   screen_factor — quarantine arrivals whose payload L2 norm
    #                   exceeds ``screen_factor`` x the round's median
    #                   arrival norm (0 = norm screen off; non-finite
    #                   payloads are always quarantined)
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    robust_agg: str = "mean"         # mean | median | trimmed_mean | norm_clip
    trim_frac: float = 0.2           # per-side trim fraction (trimmed_mean)
    clip_norm: float = 0.0           # norm_clip threshold (0 = median norm)
    quorum: float = 0.0              # 0 = no quorum gate
    screen_factor: float = 0.0       # 0 = norm screen off
    # optimization
    lr: float = 1e-3
    optimizer: str = "adam"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Distributed training-step configuration (launch layer)."""
    remat: str = "none"              # none | full | selective
    scan_layers: bool = True
    grad_accum: int = 1
    param_dtype: str = "bfloat16"
    loss_dtype: str = "float32"
    shard_lm_head_vocab: bool = True
    # NOTE: the vestigial ``use_flash_kernel`` flag was retired in favor of
    # ``ModelConfig.kernel_policy`` (xla | pallas | auto), which the round
    # engine and model facade thread through kernels/ops.py.
