"""Whisper-base transformer backbone: 6L encoder + 6L decoder, GELU,
LayerNorm, learned positions.  Mel+conv frontend STUBBED: input_specs
delivers 1500 frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
        vocab_size=51_865, activation="gelu", norm="layernorm",
        use_rope=False, max_position_embeddings=32_768,
        n_encoder_layers=6, encoder_seq_len=1500,
        citation="arXiv:2212.04356 (Whisper)")
