"""Mixtral-8x7B: 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=32_000, activation="swiglu", norm="rmsnorm",
        n_experts=8, top_k=2, sliding_window=4096,
        moe_dispatch="shard_map",  # SSPerf hillclimb 2: hybrid expert+ffn parallel
        citation="arXiv:2401.04088 (Mixtral of Experts)")
