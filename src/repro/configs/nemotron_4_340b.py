"""Nemotron-4-340B: dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense", n_layers=96,
        d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192, d_ff=73728,
        vocab_size=256_000, activation="relu2", norm="layernorm",
        citation="arXiv:2402.16819 (Nemotron-4)")
