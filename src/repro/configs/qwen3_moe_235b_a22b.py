"""Qwen3-MoE 235B-A22B: 128 experts, top-8, per-expert d_ff=1536, qk-norm
GQA [hf:Qwen/Qwen3-30B-A3B family scaled per assignment]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94,
        d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536,
        vocab_size=151_936, activation="swiglu", norm="rmsnorm",
        n_experts=128, top_k=8, qk_norm=True, rope_theta=1_000_000.0,
        moe_dispatch="shard_map",  # SSPerf hillclimb 1: 121x less collective
        citation="hf:Qwen/Qwen3-30B-A3B")
