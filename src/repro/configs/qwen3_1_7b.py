"""Qwen3-1.7B: qk-norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144,
        vocab_size=151_936, activation="swiglu", norm="rmsnorm",
        qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
        citation="hf:Qwen/Qwen3-8B")
