"""RecurrentGemma-2B (Griffin hybrid: RG-LRU + local attention, 1 attn per
3 blocks) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=26,
        d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680,
        vocab_size=256_000, activation="swiglu", norm="rmsnorm",
        layer_pattern=("rglru", "rglru", "local_attn"), local_window=2048,
        lru_width=2560, conv1d_width=4, tie_embeddings=True,
        embed_scale=True, citation="arXiv:2402.19427 (Griffin/RecurrentGemma)")
