"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
        n_heads=0, n_kv_heads=0, head_dim=64, d_ff=7168,
        vocab_size=65_536, activation="relu2", norm="layernorm",
        layer_pattern=("rwkv6",), use_rope=True,  # rwkv ignores positions
        citation="arXiv:2404.05892 (RWKV-6 Finch)")
