"""The four assigned input shapes + the decode-shape eligibility policy
(DESIGN SSDecode-shape policy)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only for sub-quadratic archs (SSM / hybrid / native
    sliding window); everything else runs all four shapes."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    if shape_supported(cfg, shape):
        return ""
    return (f"{cfg.name} is pure full-attention: a {shape.seq_len} dense KV "
            "cache is the quadratic blow-up this shape discriminates "
            "(DESIGN SSDecode-shape policy)")
