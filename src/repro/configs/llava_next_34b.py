"""LLaVA-NeXT-34B language backbone + anyres vision-token prefix (vision
tower + projector STUBBED: input_specs delivers patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf family, 34B scale per assignment]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480,
        vocab_size=64_000, activation="swiglu", norm="rmsnorm",
        n_image_tokens=576, image_embed_dim=1024,
        citation="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres tiling)")
