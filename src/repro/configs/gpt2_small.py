"""GPT-2 — the paper's case-study model (SSV) [Radford et al. 2019].

``gpt2()`` is the real 124M config; ``gpt2_tiny()`` is the reduced variant
the CI-speed case-study benchmarks run (same family: learned positions,
LayerNorm, GELU, MHA with QKV bias — the paper's LoRA target
``attn.c_attn`` corresponds to targets ("wq","wk","wv") here)."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def gpt2() -> ModelConfig:
    return ModelConfig(
        name="gpt2", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=50257, qkv_bias=True,
        activation="gelu", norm="layernorm", use_rope=False,
        max_position_embeddings=1024, tie_embeddings=True,
        citation="Radford et al., 2019 (OpenAI blog)")


def gpt2_tiny(vocab_size: int = 512) -> ModelConfig:
    return ModelConfig(
        name="gpt2-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=vocab_size,
        qkv_bias=True, activation="gelu", norm="layernorm", use_rope=False,
        max_position_embeddings=256, tie_embeddings=True,
        citation="reduced GPT-2 family for case-study benchmarks")
