"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs import (gpt2_small, llava_next_34b, mistral_large_123b,
                           mixtral_8x7b, nemotron_4_340b, qwen2_1_5b,
                           qwen3_1_7b, qwen3_moe_235b_a22b,
                           recurrentgemma_2b, rwkv6_1_6b, whisper_base)
from repro.configs.base import ModelConfig

ARCHS: Dict[str, Callable[[], ModelConfig]] = {
    "mistral-large-123b": mistral_large_123b.config,
    "recurrentgemma-2b": recurrentgemma_2b.config,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b.config,
    "mixtral-8x7b": mixtral_8x7b.config,
    "llava-next-34b": llava_next_34b.config,
    "qwen2-1.5b": qwen2_1_5b.config,
    "qwen3-1.7b": qwen3_1_7b.config,
    "rwkv6-1.6b": rwkv6_1_6b.config,
    "whisper-base": whisper_base.config,
    "nemotron-4-340b": nemotron_4_340b.config,
    # the paper's own case-study model
    "gpt2": gpt2_small.gpt2,
    "gpt2-tiny": gpt2_small.gpt2_tiny,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]()
