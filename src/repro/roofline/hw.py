"""TPU v5e hardware constants (charter ROOFLINE ANALYSIS)."""

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16 * 2**30          # v5e HBM capacity


def compute_time_s(flops: float, chips: int) -> float:
    return flops / (chips * PEAK_FLOPS_BF16)


def memory_time_s(bytes_: float, chips: int) -> float:
    return bytes_ / (chips * HBM_BW)


def collective_time_s(bytes_: float, chips: int) -> float:
    return bytes_ / (chips * ICI_BW)
