"""Parse collective ops out of post-SPMD HLO text.

``cost_analysis()`` has no collective view, so we sum the operand/result
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the compiled module (charter ROOFLINE ANALYSIS).

HLO result lines look like:
    %all-gather.3 = bf16[16,4096,1024]{2,1,0} all-gather(...)
Tuple-typed collectives:  (bf16[...], bf16[...]) all-reduce(...)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# one shaped buffer, e.g. bf16[16,4096,1024]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+(" + "|".join(COLLECTIVES)
    + r")(\.|\()")


def _buffer_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result-buffer bytes per collective kind (per-device view —
    post-SPMD shapes are already the per-shard shapes)."""
    out: Dict[str, int] = defaultdict(int)
    for m in _LINE_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _buffer_bytes(type_str)
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())
