"""Roofline analysis (charter deliverable g).

``cost_analysis()`` counts ``while``/scan bodies ONCE (not x trip count),
so the full-model scanned compile — the fits/coherence proof — undercounts
FLOPs by ~n_layers.  This module therefore lowers a *stem* (0 layers) and
a *one-pattern-group* variant of each arch unrolled, subtracts, and scales
by the layer count:

    total = stem + (group - stem) * (n_layers / len(pattern))

Small models (<= 12 total layers) are lowered fully unrolled — exact.
Collective bytes come from the same unrolled HLO (roofline/collectives).

Per (arch x shape x mesh) we report the three roofline terms:
    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * 50 GB/s/link)
plus MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) and the
MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.shapes import SHAPES
from repro.launch import steps as steps_mod
from repro.launch.mesh import (activate_mesh, cost_analysis_dict,
                               make_production_mesh)
from repro.models import common
from repro.roofline import collectives as coll_mod
from repro.roofline import hw

UNROLL_LIMIT = 12     # lower fully-unrolled when total layers <= this


def _lower(cfg, shape, mesh, remat="full", step_override=None):
    with activate_mesh(mesh):
        common.enable_shard_hints(True)
        try:
            fn, args, shardings = steps_mod.build_step(
                cfg, shape, mesh, scan_layers=False, remat=remat)
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            compiled = lowered.compile()
        finally:
            common.enable_shard_hints(False)
    ca = cost_analysis_dict(compiled)
    text = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(coll_mod.total_collective_bytes(text)),
        "coll_by_kind": coll_mod.collective_bytes(text),
    }


def _variant(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    enc = min(cfg.n_encoder_layers, n_layers) if cfg.n_encoder_layers else 0
    return dataclasses.replace(cfg, n_layers=n_layers,
                               n_encoder_layers=enc)


@dataclasses.dataclass
class RooflineTerms:
    """XLA's ``cost_analysis()`` on an SPMD-partitioned module reports
    PER-DEVICE flops/bytes (verified: per-device ~= global/chips), and the
    post-SPMD HLO collective shapes are per-shard too — so each term
    divides by a single chip's peak; the charter's ``/(chips x peak)`` is
    already folded into the per-device numbers."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    collective_bytes: float   # per device
    model_flops: float        # GLOBAL analytic 6ND / 2ND
    # seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def __post_init__(self):
        self.t_compute = hw.compute_time_s(self.hlo_flops, 1)
        self.t_memory = hw.memory_time_s(self.hlo_bytes, 1)
        self.t_collective = hw.collective_time_s(self.collective_bytes, 1)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — how much of compiled compute is
        'useful' (catches remat/redundancy waste).  < 1 when the compiled
        program does extra work (remat ~ x1.33, attention, dispatch)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def analyze(arch_cfg: ModelConfig, shape_name: str,
            multi_pod: bool = False, remat: str = "full",
            verbose: bool = True) -> RooflineTerms:
    cfg = arch_cfg
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    pat = len(cfg.layer_pattern or (1,))
    total_layers = cfg.n_layers + cfg.n_encoder_layers

    if total_layers <= UNROLL_LIMIT:
        full = _lower(cfg, shape, mesh, remat)
        flops, bytes_, coll = full["flops"], full["bytes"], full["coll"]
    else:
        stem = _lower(_variant(cfg, 0), shape, mesh, remat)
        group = _lower(_variant(cfg, pat), shape, mesh, remat)
        scale = cfg.n_layers / pat
        flops = stem["flops"] + (group["flops"] - stem["flops"]) * scale
        bytes_ = stem["bytes"] + (group["bytes"] - stem["bytes"]) * scale
        coll = stem["coll"] + (group["coll"] - stem["coll"]) * scale

    terms = RooflineTerms(
        arch=cfg.name, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_, collective_bytes=coll,
        model_flops=model_flops_for(cfg, shape))
    if verbose:
        r = terms
        print(f"{cfg.name} x {shape_name}: compute={r.t_compute*1e3:.1f}ms "
              f"memory={r.t_memory*1e3:.1f}ms "
              f"collective={r.t_collective*1e3:.1f}ms "
              f"-> {r.dominant}-bound, useful={r.useful_ratio:.2f}")
    return terms
