"""SPMD federated round — the hardware-adapted FedLLM (DESIGN SS2).

The paper's clients are edge devices; on a TPU fleet a "client" is a pod
(or mesh slice).  Here one jitted program runs EVERY client's local
epoch simultaneously (clients = leading axis, vmapped) and performs the
FedAvg aggregation as a mean over that axis — which, with the client
axis sharded over the multi-pod mesh's ``pod`` dimension, lowers to a
single cross-pod all-reduce: the parameter-server round of the paper
becomes one collective.  This is the beyond-paper execution mode used by
the ``fed_round`` dry-run target (launch/dryrun.py --step fed_round).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import tasks
from repro.models.factory import Model
from repro.optim import adam
from repro.peft import lora as lora_lib


def make_spmd_round(model: Model, fed: FedConfig,
                    task: str = "classification"):
    """Returns round_step(base, stacked_lt, stacked_opt, batches) where
    stacked_* have a leading client axis C and ``batches`` leaves are
    (C, n_steps, B, ...).  Output LoRA is already aggregated (identical
    across the client axis, like a1 of the next round)."""
    cfg = model.cfg
    task_loss = tasks.get_loss_fn(task)

    def local_update(base, lt, opt, client_batches):
        def body(carry, batch):
            lt, opt = carry

            def loss_fn(l):
                bound = lora_lib.bind(base, l, fed.lora_alpha,
                                      fed.lora_rank)
                logits, aux = model.forward(bound, batch)
                loss, _ = task_loss(logits, batch)
                return loss + aux

            loss, grads = jax.value_and_grad(loss_fn)(lt)
            lt, opt = adam.update(grads, opt, lt, fed.lr)
            return (lt, opt), loss

        (lt, opt), losses = jax.lax.scan(body, (lt, opt), client_batches)
        return lt, opt, jnp.mean(losses)

    def round_step(base, stacked_lt, stacked_opt, batches):
        new_lt, new_opt, losses = jax.vmap(
            local_update, in_axes=(None, 0, 0, 0))(
                base, stacked_lt, stacked_opt, batches)
        # a4: FedAvg == mean over the client axis -> cross-pod all-reduce
        avg = jax.tree.map(lambda x: jnp.mean(x, axis=0), new_lt)
        # a1 of the next round: broadcast back to every client slot
        C = jax.tree.leaves(stacked_lt)[0].shape[0]
        redist = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), avg)
        return redist, new_opt, losses

    return round_step


def stack_for_clients(tree, n_clients: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), tree)
