"""SPMD federated rounds — the hardware-adapted execution backend for
all three paper frameworks (DESIGN SS2, ``FedConfig(backend="spmd")``).

The paper's clients are edge devices; on a TPU fleet a "client" is a pod
(or mesh slice).  Here one jitted program runs EVERY client's local work
simultaneously (clients = leading axis, vmapped) and performs the
server-side aggregation as a reduction over that axis — which, with the
client axis sharded over the multi-pod mesh's ``pod`` dimension, lowers
to a single cross-pod all-reduce: the parameter-server round of the
paper becomes one collective.

Per framework:

- FedLLM (``make_spmd_round``): vmapped local fine-tune scans + weighted
  FedAvg as a client-axis mean.
- KD-FedLLM (``make_kd_spmd_fns``): vmapped local fine-tune, batched
  logit production on the public set, and vmapped client-side
  distillation; knowledge aggregation is the client-axis reduction in
  ``kd.aggregate_knowledge_batched``.
- Split-FedLLM (``make_split_spmd_round``): stacked client-side LoRA
  halves with ONE shared server half.  The server carry scans the client
  axis (the paper's round trains the shared server layers
  client-after-client, so a lockstep-parallel server would change the
  optimization trajectory); the closing FedAvg of the client halves is
  still a client-axis reduction.

Clients with ragged batch counts are padded and masked (``valid``): a
masked step returns the carry unchanged, so every client performs
exactly the step sequence the sequential backend would.  Host-side
drivers live in core/rounds_spmd.py; the ``fed_round`` dry-run target
(launch/dryrun.py --step fed_round) compiles these programs against the
production meshes.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data.loader import epoch_batches
from repro.models.factory import Model


# --------------------------------------------------------------------------- #
# Stacking utilities (host side)
# --------------------------------------------------------------------------- #
def stack_for_clients(tree, n_clients: int):
    """Broadcast one tree to a leading client axis (a1: distribute)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), tree)


def stack_trees(trees: Sequence):
    """Stack identically-structured per-client trees on a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def split_keys(key, n_clients: int, n_steps: int):
    """(C, S) grid of PRNG keys (works for legacy and typed key arrays)."""
    keys = jax.random.split(key, n_clients * n_steps)
    return keys.reshape((n_clients, n_steps) + keys.shape[1:])


def split_each(stacked_keys):
    """Per-client ``jax.random.split``: (C,)-stacked keys -> (next, sub)."""
    out = jax.vmap(jax.random.split)(stacked_keys)
    return out[:, 0], out[:, 1]


def stack_client_batches(clients_data: List[Dict], batch_size: int,
                         seeds: Sequence[int]):
    """Materialize every client's shuffled epoch batches as stacked
    arrays with a leading (client, step) axis plus a validity mask.

    ``seeds`` is the per-epoch seed sequence handed to ``epoch_batches``
    — the same one the sequential backend uses, so each client sees the
    exact same batch order under both backends.  Clients with fewer
    batches than the longest are padded by repeating their last batch
    with ``valid=False``; the scanned round step drops those updates, so
    per-client step counts are preserved exactly.

    Returns ``(batches, valid, n_tok)``: batches leaves are
    (C, S, B, ...) jnp arrays, ``valid`` a (C, S) bool ndarray, and
    ``n_tok`` the per-client real token counts for the cost model.
    """
    per_client = []
    for data in clients_data:
        client_batches = []
        for seed in seeds:
            client_batches.extend(epoch_batches(data, batch_size, seed=seed))
        per_client.append(client_batches)
    n_steps = [len(b) for b in per_client]
    if min(n_steps) == 0:
        raise ValueError(
            "spmd backend: every client needs at least one full batch "
            f"(batch_size={batch_size}, client sizes="
            f"{[len(d['tokens']) for d in clients_data]})")
    n_tok = [sum(b["tokens"].size for b in bs) for bs in per_client]
    S = max(n_steps)
    valid = np.zeros((len(per_client), S), bool)
    rows = []
    for ci, bs in enumerate(per_client):
        valid[ci, :len(bs)] = True
        padded = bs + [bs[-1]] * (S - len(bs))
        rows.append({k: np.stack([b[k] for b in padded]) for k in bs[0]})
    batches = {k: jnp.asarray(np.stack([r[k] for r in rows]))
               for k in rows[0]}
    return batches, valid, n_tok


def unstack_tree(stacked):
    """Inverse of ``stack_trees``: a list of per-client trees from a
    leading-axis stack (host-side seam between bucketed programs and the
    cross-bucket harmonization)."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def rank_buckets(ranks: Sequence[int], clients: Sequence[int] = None):
    """Group client indices by LoRA rank: ``[(rank, [client, ...]), ...]``
    ordered by first occurrence, client order preserved within a bucket.
    Each bucket runs as one jitted stacked program (clients in a bucket
    share tree shapes, so they stack on a leading axis)."""
    if clients is None:
        clients = range(len(ranks))
    out: Dict[int, List[int]] = {}
    for ci in clients:
        out.setdefault(ranks[ci], []).append(ci)
    return list(out.items())


def rank_segments(ranks: Sequence[int], clients: Sequence[int] = None):
    """Maximal runs of equal-rank clients in visit order:
    ``[(rank, [client, ...]), ...]``.  Split-FedLLM buckets this way —
    the shared server half is trained client-after-client (paper
    schedule), so only contiguous equal-rank runs may fuse into one
    stacked program without reordering the server-half trajectory."""
    segs: List = []
    if clients is None:
        clients = range(len(ranks))
    for ci in clients:
        if segs and ranks[ci] == segs[-1][0]:
            segs[-1][1].append(ci)
        else:
            segs.append((ranks[ci], [ci]))
    return segs


def _select(ok, new, old):
    """Keep ``new`` where the step was real, the carry otherwise."""
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


def _normalized(weights):
    """Weights normalized to sum 1, degrading to uniform when the total
    is zero (a fully-dropped cohort must not turn the aggregate into
    NaN).  Bit-transparent for any positive total: the guarded divisor
    equals the plain sum, so existing parity pins are unaffected."""
    w = weights.astype(jnp.float32)
    s = w.sum()
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0),
                     1.0 / w.shape[0])


def weighted_client_mean(stacked_tree, weights):
    """FedAvg as a reduction over the leading client axis (fp32 accum,
    like core/fedavg.fedavg) — one all-reduce when that axis is sharded."""
    w = _normalized(weights)

    def mean(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (wx * x.astype(jnp.float32)).sum(axis=0).astype(x.dtype)

    return jax.tree.map(mean, stacked_tree)


def hierarchical_client_mean(stacked_tree, weights, n_edges: int):
    """FedAvg as the two-hop reduction of a real cross-device topology:
    the client axis is reshaped to (edges, clients_per_edge), each edge
    reduces its own clients to a weighted partial sum (a per-pod
    ``psum`` when the client axis is sharded over the mesh's ``pod``
    axis — the per-edge slice is pod-local by construction), and the
    per-edge partials fold through a pairwise halving tree (log2(edges)
    cross-pod combine steps, unrolled at trace time).

    Numerically this reassociates the fp32 accumulation of
    ``weighted_client_mean`` — same normalized weights, same fp32
    accum, fp32-tolerant agreement — while lowering to the per-pod
    reduce + cross-pod tree the hierarchical topology actually runs.
    Degenerates to the flat reduction when ``n_edges <= 1`` or the
    client count doesn't tile the edges."""
    C = weights.shape[0]
    if n_edges <= 1 or C % n_edges:
        return weighted_client_mean(stacked_tree, weights)
    we = _normalized(weights).reshape(n_edges, C // n_edges)

    def mean(x):
        xe = x.reshape((n_edges, C // n_edges) + x.shape[1:])
        wx = we.reshape(we.shape + (1,) * (x.ndim - 1))
        part = (wx * xe.astype(jnp.float32)).sum(axis=1)   # per-edge psum
        while part.shape[0] > 1:                           # cross-edge tree
            m = part.shape[0] // 2
            part = jnp.concatenate(
                [part[:m] + part[m:2 * m], part[2 * m:]], axis=0)
        return part[0].astype(x.dtype)

    return jax.tree.map(mean, stacked_tree)


# --------------------------------------------------------------------------- #
# Byzantine-robust client-axis reductions (src/repro/faults/)
# --------------------------------------------------------------------------- #
def robust_client_combine(stacked_tree, weights, method: str,
                          trim_frac: float = 0.2, clip_norm: float = 0.0):
    """Byzantine-robust drop-in for ``weighted_client_mean`` over the
    stacked client axis (``FedConfig.robust_agg``):

    - ``median``: coordinate-wise median.  Unweighted — order statistics
      ignore data weights; tolerates < C/2 corrupt clients per
      coordinate.
    - ``trimmed_mean``: per coordinate, sort the client axis and drop
      ``floor(trim_frac * C)`` values from each end before the
      (unweighted) mean; tolerates up to the trimmed count corrupt.
    - ``norm_clip``: clip each client update's global L2 norm to
      ``clip_norm`` (0 = the cohort's median norm), then take the
      usual weighted mean — bounds any single client's pull without
      discarding honest heavy updates.

    All methods accumulate in fp32 and cast back to the leaf dtype,
    like the plain mean.  They never change payload shapes, so ledger
    bytes under a robust aggregate match the plain engines exactly.
    """
    if method in ("mean", None, ""):
        return weighted_client_mean(stacked_tree, weights)
    C = jax.tree.leaves(stacked_tree)[0].shape[0]
    if method == "median":
        return jax.tree.map(
            lambda x: jnp.median(x.astype(jnp.float32), axis=0)
            .astype(x.dtype), stacked_tree)
    if method == "trimmed_mean":
        k = int(trim_frac * C)
        if 2 * k >= C:
            k = (C - 1) // 2

        def tmean(x):
            s = jnp.sort(x.astype(jnp.float32), axis=0)
            return s[k:C - k].mean(axis=0).astype(x.dtype)

        return jax.tree.map(tmean, stacked_tree)
    if method == "norm_clip":
        sq = sum(jnp.square(x.astype(jnp.float32))
                 .reshape(C, -1).sum(axis=1)
                 for x in jax.tree.leaves(stacked_tree))
        norms = jnp.sqrt(sq)                                   # (C,)
        tau = jnp.asarray(clip_norm, jnp.float32) if clip_norm > 0 \
            else jnp.median(norms)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-12))
        clipped = jax.tree.map(
            lambda x: (scale.reshape((-1,) + (1,) * (x.ndim - 1))
                       * x.astype(jnp.float32)).astype(x.dtype),
            stacked_tree)
        return weighted_client_mean(clipped, weights)
    raise ValueError(f"unknown robust_agg {method!r}")


def client_combine(stacked_tree, weights, fed: FedConfig):
    """The round's configured client-axis reduction: the plain weighted
    mean, or the Byzantine-robust combine when ``fed.robust_agg`` says
    so.  Robust statistics do not decompose over edges, so a robust
    combine is always the flat (single-hop) reduction — hierarchical
    runs fall back to it whole-cohort."""
    if fed.robust_agg != "mean":
        return robust_client_combine(stacked_tree, weights, fed.robust_agg,
                                     fed.trim_frac, fed.clip_norm)
    return weighted_client_mean(stacked_tree, weights)


# --------------------------------------------------------------------------- #
# Shared local-update machinery (FedLLM a2 / KD b1)
# --------------------------------------------------------------------------- #
def make_local_update(model: Model, fed: FedConfig,
                      task: str = "classification"):
    """Returns local_update(base, lt, opt, batches, keys, valid) scanning
    one client's batch sequence — the building block vmapped over the
    client axis by every SPMD round.  The per-batch step is the
    sequential backend's own train_step body (fedavg.make_fns), so the
    backends can never drift apart on the local loss/optimizer."""
    from repro.core.fedavg import make_fns

    train_step = make_fns(model, fed, task)["train_step_impl"]

    def local_update(base, lt, opt, client_batches, keys, valid):
        def body(carry, step):
            lt, opt = carry
            batch, key, ok = step
            new_lt, new_opt, loss = train_step(base, lt, opt, batch, key)
            return (_select(ok, new_lt, lt), _select(ok, new_opt, opt)), \
                jnp.where(ok, loss, 0.0)

        (lt, opt), losses = jax.lax.scan(
            body, (lt, opt), (client_batches, keys, valid))
        return lt, opt, losses.sum() / jnp.maximum(valid.sum(), 1)

    return local_update


def make_bucket_update(model: Model, fed: FedConfig,
                       task: str = "classification"):
    """jit(vmap(local_update)) WITHOUT the closing FedAvg: the building
    block for per-rank bucketing and async participation, where the
    cross-client aggregation happens on the host across buckets
    (core/heterogeneous.harmonize_buckets / core/async_agg).  One
    program object — jax recompiles per (bucket size, rank, n_steps)
    signature and caches each variant."""
    local_update = make_local_update(model, fed, task)
    return jax.jit(jax.vmap(local_update, in_axes=(None, 0, 0, 0, 0, 0)))


# --------------------------------------------------------------------------- #
# 1) FedLLM round (a1-a4)
# --------------------------------------------------------------------------- #
def make_spmd_round(model: Model, fed: FedConfig,
                    task: str = "classification", n_edges: int = 1):
    """Returns round_step(base, stacked_lt, stacked_opt, batches, keys,
    valid, weights[, noise_keys]) where stacked_* have a leading client
    axis C and ``batches`` leaves are (C, n_steps, B, ...).  Output LoRA
    is already aggregated and redistributed (identical across the client
    axis, like a1 of the next round); the pre-aggregation *uploaded*
    trees come back too, so the host can run the secure-agg masking
    overlay and the per-client wire accounting on exactly what crossed
    the wire.

    With ``PrivacyConfig`` noise active the extra ``noise_keys`` input
    is one key per client slot (privacy/dp.noise_key — the same keys
    the sequential backend folds in), and the DP payload noise is added
    to every client's tree *before* the client-axis FedAvg, mirroring
    the a3 upload boundary.

    ``n_edges > 1`` swaps the closing a4 reduction for the two-hop
    ``hierarchical_client_mean`` — per-edge (per-pod) partial sums
    feeding a cross-edge pairwise tree — matching the client -> edge ->
    server topology the launch layer compiles on multi-pod meshes."""
    local_update = make_local_update(model, fed, task)
    noise_std = fed.privacy.noise_std

    def round_step(base, stacked_lt, stacked_opt, batches, keys, valid,
                   weights, noise_keys=None):
        new_lt, new_opt, losses = jax.vmap(
            local_update, in_axes=(None, 0, 0, 0, 0, 0))(
                base, stacked_lt, stacked_opt, batches, keys, valid)
        if noise_std > 0.0:
            from repro.privacy import dp as dp_mod
            new_lt = jax.vmap(
                lambda t, k: dp_mod.privatize_tree(t, k, noise_std))(
                    new_lt, noise_keys)
        # a4: weighted FedAvg == client-axis reduction -> all-reduce
        # (or the per-pod psum + cross-pod tree when edges are in play;
        # a robust_agg overrides both — order statistics don't
        # decompose over edges, so the robust combine is always flat)
        if fed.robust_agg != "mean":
            avg = client_combine(new_lt, weights, fed)
        else:
            avg = hierarchical_client_mean(new_lt, weights, n_edges) \
                if n_edges > 1 else weighted_client_mean(new_lt, weights)
        # a1 of the next round: broadcast back to every client slot
        C = jax.tree.leaves(stacked_lt)[0].shape[0]
        redist = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), avg)
        return redist, new_opt, losses, new_lt

    return round_step


# --------------------------------------------------------------------------- #
# 2) KD-FedLLM stages (b1/b2/b8 batched over clients)
# --------------------------------------------------------------------------- #
def make_kd_spmd_fns(model: Model, fed: FedConfig,
                     task: str = "classification"):
    """Batched KD-FedLLM stages, clients on the leading axis:

    - client_update(base, slt, sopt, batches, keys, valid): vmapped b1
      local fine-tuning (each client scans its own private batches).
    - batched_logits(base, slt, public_batch): b2/b6 knowledge
      production for every client at once -> (C, B, D).
    - batched_kd_step(base, slt, sopt, public_batch, teacher, keys):
      one vmapped b8 distillation step against shared global knowledge.

    Knowledge aggregation (b4) is ``kd.aggregate_knowledge_batched``.
    """
    from repro.core.fedavg import make_fns

    fns = make_fns(model, fed, task)
    local_update = make_local_update(model, fed, task)
    client_update = jax.jit(jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, 0, 0)))
    batched_logits = jax.jit(jax.vmap(
        fns["logits_fn"], in_axes=(None, 0, None)))
    batched_kd_step = jax.jit(jax.vmap(
        fns["kd_step"], in_axes=(None, 0, 0, None, None, 0)))
    return {"client_update": client_update,
            "batched_logits": batched_logits,
            "batched_kd_step": batched_kd_step}


# --------------------------------------------------------------------------- #
# 3) Split-FedLLM round (c1-c5 + cc1-cc4)
# --------------------------------------------------------------------------- #
def make_split_spmd_round(model: Model, fed: FedConfig,
                          task: str = "classification", sfns=None,
                          client_sharding=None):
    """One program for the whole Split-FedLLM round.

    Client-side LoRA halves are stacked on a leading client axis and the
    closing FedAvg (cc2) is a weighted reduction over it.  The shared
    server half is a carry scanned over the client axis — the paper's
    round trains the server layers client-after-client, and preserving
    that order keeps the SPMD backend numerically equivalent to the
    sequential one (a lockstep-parallel server is a different algorithm,
    not an execution backend).

    Returns round_step(base_c, base_s, c_global, s_lt, s_opt, batches,
    keys, valid, weights[, nkeys]) -> (new_c_global, s_lt, s_opt,
    losses, stacked_c).  ``stacked_c`` is the per-client uploaded
    half (for the host's secure-agg overlay); ``nkeys`` is the
    (C, S)-stacked privacy noise-key grid consumed by the c2 activation
    mechanism when DP noise is active — the same per-(client, step)
    fold_in stream the sequential backend passes, so noise is
    bit-identical across backends.

    ``client_sharding(ndim) -> NamedSharding`` (optional) pins the
    stacked client-half axis to the mesh's client axes before the
    closing cc2 reduction: the scan emits the per-client halves, the
    constraint lays them out client-sharded, and the FedAvg lowers to a
    cross-client all-reduce (launch/steps.py passes this for the
    mesh-sharded dry-run).
    """
    from repro.core import split as split_mod

    if sfns is None:
        sfns = split_mod.make_split_fns(model, fed, task)
    step = sfns["split_step"]
    opt_init = sfns["opt_init"]
    noised = fed.privacy.noise_std > 0.0

    def round_step(base_c, base_s, c_global, s_lt, s_opt, batches, keys,
                   valid, weights, nkeys=None):
        def per_client(carry, client):
            s_lt, s_opt = carry

            def body(inner, x):
                c_lt, c_opt, s_lt, s_opt = inner
                batch, key, ok = x[:3]
                nk = x[3] if noised else None
                nc, ns, nco, nso, loss = step(base_c, base_s, c_lt, s_lt,
                                              c_opt, s_opt, batch, key, nk)
                return (_select(ok, nc, c_lt), _select(ok, nco, c_opt),
                        _select(ok, ns, s_lt), _select(ok, nso, s_opt)), \
                    jnp.where(ok, loss, 0.0)

            # cc3: fresh client copy of the global client-side LoRA
            (c_lt, _, s_lt, s_opt), losses = jax.lax.scan(
                body, (c_global, opt_init(c_global), s_lt, s_opt), client)
            return (s_lt, s_opt), (c_lt, losses)

        xs = (batches, keys, valid) + ((nkeys,) if noised else ())
        (s_lt, s_opt), (stacked_c, losses) = jax.lax.scan(
            per_client, (s_lt, s_opt), xs)
        if client_sharding is not None:
            stacked_c = jax.lax.with_sharding_constraint(
                stacked_c,
                jax.tree.map(lambda x: client_sharding(x.ndim), stacked_c))
        # cc2: FedAvg of the client halves — client-axis reduction
        # (robust combine when configured)
        new_c_global = client_combine(stacked_c, weights, fed)
        return new_c_global, s_lt, s_opt, losses, stacked_c

    return round_step


def make_split_spmd_segment(model: Model, fed: FedConfig,
                            task: str = "classification", sfns=None):
    """One stacked program for a contiguous equal-rank client *segment*
    of a heterogeneous Split-FedLLM round (``rank_segments``).

    Like ``make_split_spmd_round``'s scan, but (1) every client starts
    from ``c_init`` — the global client half already truncated to the
    segment's rank — and (2) the closing FedAvg is left to the host,
    which harmonizes ranks across segments.  The server carry enters
    and leaves the program, so threading it segment-after-segment
    reproduces the sequential backend's exact client visit order.

    Returns seg_step(base_c, base_s, c_init, s_lt, s_opt, batches, keys,
    valid[, nkeys]) -> (stacked_c, s_lt, s_opt, losses).  ``nkeys`` as
    in ``make_split_spmd_round``: the (|seg|, S) privacy noise-key grid
    for the c2 activation mechanism when DP noise is active.
    """
    from repro.core import split as split_mod

    if sfns is None:
        sfns = split_mod.make_split_fns(model, fed, task)
    step = sfns["split_step"]
    opt_init = sfns["opt_init"]
    noised = fed.privacy.noise_std > 0.0

    def seg_step(base_c, base_s, c_init, s_lt, s_opt, batches, keys,
                 valid, nkeys=None):
        def per_client(carry, client):
            s_lt, s_opt = carry

            def body(inner, x):
                c_lt, c_opt, s_lt, s_opt = inner
                batch, key, ok = x[:3]
                nk = x[3] if noised else None
                nc, ns, nco, nso, loss = step(base_c, base_s, c_lt, s_lt,
                                              c_opt, s_opt, batch, key, nk)
                return (_select(ok, nc, c_lt), _select(ok, nco, c_opt),
                        _select(ok, ns, s_lt), _select(ok, nso, s_opt)), \
                    jnp.where(ok, loss, 0.0)

            (c_lt, _, s_lt, s_opt), losses = jax.lax.scan(
                body, (c_init, opt_init(c_init), s_lt, s_opt), client)
            return (s_lt, s_opt), (c_lt, losses)

        xs = (batches, keys, valid) + ((nkeys,) if noised else ())
        (s_lt, s_opt), (stacked_c, losses) = jax.lax.scan(
            per_client, (s_lt, s_opt), xs)
        return stacked_c, s_lt, s_opt, losses

    return seg_step
