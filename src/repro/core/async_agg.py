"""Asynchronous, staleness-aware aggregation (``FedConfig(aggregation=
"async")``) — the participation/staleness *model* of the round pipeline
(cf. "Federated LLMs: Current Progress and Future Directions",
arXiv:2409.15723): real fleets never deliver every client's update in
lockstep, so the server must fold in *late* knowledge without stalling
the round clock.

Simulation model (FedAsync-style, deterministic under ``FedConfig.seed``):

- ``ParticipationSchedule`` gives every client a seeded per-job delay.
  A free client *starts* a job each round: it pulls the current global
  state, trains locally NOW (so its update reflects the global it saw),
  and the update goes in flight for ``delay`` rounds.
- At each round the server aggregates the updates that *arrive*, each
  weighted by its data weight times the polynomial staleness decay
  ``(1 + s)^-staleness_decay`` where ``s = arrival - start`` rounds.
  Updates staler than ``max_staleness`` are discarded (the client simply
  re-syncs).  The mass of clients that delivered nothing this round
  anchors the current global, so a lone stale straggler cannot yank the
  model.
- ``max_staleness == 0`` forces fully synchronous participation, which
  makes the async schedule coincide with the sync one *exactly* at
  ``lora_dropout == 0`` (tests/test_async_agg.py) — the knob
  interpolates between the paper-literal round and a realistic fleet.

The staleness treatment is uniform across the three frameworks — what
differs is the payload in flight: LoRA **params** for FedLLM, public-set
**logits** for KD-FedLLM, and **client-half adapters** for Split-FedLLM
(activations/grad traffic stays synchronous inside the training round:
the server's half is in the loop while a split client trains).

Since the RoundProgram refactor this module only holds the *model* —
the delay schedule, the in-flight job bookkeeping and the
staleness-weighted aggregation — which core/round_program.py's
``AsyncSchedule`` composes with any framework x executor.  Both
execution backends therefore share one driver by construction, ledgers
agree across backends, and heterogeneous ``client_ranks`` compose
freely with async (stale hetero updates harmonize through
``aggregate_hetero``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import rng as rng_mod
from repro.core.fedavg import fedavg
from repro.core.heterogeneous import aggregate_hetero


# --------------------------------------------------------------------------- #
# Participation schedule + staleness weights
# --------------------------------------------------------------------------- #
class ParticipationSchedule:
    """Deterministic per-client availability/delay model.

    Client speed is a per-client *trait*: a "slowness" drawn once from
    the master seed gives each client a Binomial(max_staleness + 1,
    slowness) delay per job — fast clients usually deliver in the round
    they start, slow clients lag several rounds and occasionally exceed
    ``max_staleness`` (the server discards those updates).  Per-client
    generators are consumed one draw per started job, so a fixed seed
    replays the identical schedule on any backend."""

    def __init__(self, n_clients: int, seed: int = 0,
                 max_staleness: int = 4):
        master = np.random.default_rng(seed)
        self.slowness = master.uniform(0.15, 0.85, n_clients)
        self.max_staleness = int(max_staleness)
        self._rngs = [np.random.default_rng((seed, 7919, ci))
                      for ci in range(n_clients)]

    def next_delay(self, ci: int) -> int:
        """Rounds until client ``ci``'s freshly started job delivers
        (0 = the same round it trains)."""
        if self.max_staleness <= 0:
            return 0
        return int(self._rngs[ci].binomial(self.max_staleness + 1,
                                           self.slowness[ci]))

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def state(self) -> List[dict]:
        """Per-client generator states — the only mutable part (the
        slowness traits re-derive from the seed)."""
        return [g.bit_generator.state for g in self._rngs]

    def load_state(self, states: List[dict]):
        for g, st in zip(self._rngs, states):
            g.bit_generator.state = st


def staleness_weight(staleness: int, decay: float) -> float:
    """Polynomial staleness decay (FedAsync): ``(1 + s)^-decay``."""
    return float((1.0 + staleness) ** (-decay))


@dataclasses.dataclass
class _Job:
    """One in-flight client update."""
    client: int
    start: int          # round the client pulled the global + trained
    arrival: int        # round the update lands on the server
    payload: object     # params / logits / client-half adapters


def _pop_arrivals(in_flight: Dict[int, _Job], rnd: int) -> List[_Job]:
    """Jobs delivering this round, in client visit order."""
    arrived = sorted((j for j in in_flight.values() if j.arrival == rnd),
                     key=lambda j: j.client)
    for j in arrived:
        del in_flight[j.client]
    return arrived


def stale_weighted_avg(global_tree, arrivals, total_weight: float, fed,
                       ranks: List[int]):
    """Staleness-weighted FedAvg of arrived parameter trees.

    ``arrivals`` is a list of ``(client, tree, staleness, data_weight)``
    already filtered to ``staleness <= max_staleness``.  The data weight
    of every client that delivered nothing this round anchors the
    current global tree, so the update is a convex combination that
    degenerates to plain (hetero-aware) FedAvg when everyone arrives
    fresh — the sync-equivalence property the tests pin down."""
    trees = [t for _, t, _, _ in arrivals]
    rks = [ranks[ci] for ci, _, _, _ in arrivals]
    ws = [w * staleness_weight(s, fed.staleness_decay)
          for _, _, s, w in arrivals]
    absent = total_weight - sum(w for _, _, _, w in arrivals)
    if absent > 0:
        trees = [global_tree] + trees
        rks = [fed.lora_rank] + rks
        ws = [absent] + ws
    if any(r != fed.lora_rank for r in rks):
        return aggregate_hetero(trees, rks, fed.lora_alpha, fed.lora_rank,
                                ws, fed.hetero_agg)
    return fedavg(trees, ws)


def robust_stale_combine(global_tree, arrivals, total_weight: float, fed,
                         ranks: List[int]):
    """Byzantine-robust counterpart of ``stale_weighted_avg``.

    The robust statistic (``fed_spmd.robust_client_combine``) runs over
    the *arrived* updates only — anchoring absent mass on the current
    global inside a median/trim would let the anchor masquerade as a
    client — and the result is then blended with the current global by
    the staleness-weighted arrived mass ``rho``, preserving the async
    semantics that a thin round moves the model only a little.  When
    everyone arrives fresh (``rho == 1``) the result is exactly the
    robust combine of the cohort.  Heterogeneous ranks are zero-padded
    to the global rank first (order statistics need one client axis)."""
    import jax
    import jax.numpy as jnp

    from repro.core import fed_spmd
    from repro.peft import lora as lora_lib

    trees = []
    for ci, t, _, _ in arrivals:
        if ranks[ci] != fed.lora_rank:
            t = lora_lib.pad_rank(t, fed.lora_rank)
        trees.append(t)
    ws = [w * staleness_weight(s, fed.staleness_decay)
          for _, _, s, w in arrivals]
    agg = fed_spmd.robust_client_combine(
        fed_spmd.stack_trees(trees), jnp.asarray(ws, jnp.float32),
        fed.robust_agg, fed.trim_frac, fed.clip_norm)
    absent = total_weight - sum(w for _, _, _, w in arrivals)
    if absent <= 0:
        return agg
    rho = sum(ws) / (absent + sum(ws))
    return jax.tree.map(
        lambda g, a: ((1.0 - rho) * g.astype(jnp.float32)
                      + rho * a.astype(jnp.float32)).astype(g.dtype),
        global_tree, agg)


def combine_arrivals(global_tree, arrivals, total_weight: float, fed,
                     ranks: List[int]):
    """The round's configured host-side combine: plain staleness-weighted
    (hetero-aware) FedAvg, or the robust path when ``fed.robust_agg``
    asks for one."""
    if getattr(fed, "robust_agg", "mean") != "mean" and arrivals:
        return robust_stale_combine(global_tree, arrivals, total_weight,
                                    fed, ranks)
    return stale_weighted_avg(global_tree, arrivals, total_weight, fed,
                              ranks)


def _local_rng(fed, rnd: int, ci: int):
    """Per-(client, round) dropout RNG — kept as an alias of the shared
    core/rng helper (the single source of truth for the key tree)."""
    return rng_mod.local_rng(fed, rnd, ci)


# --------------------------------------------------------------------------- #
# Entry point (core/rounds.run_federated dispatches here) — a thin
# adapter over the unified pipeline
# --------------------------------------------------------------------------- #
def run_async(model, base, cfg, fed, targets, public: Dict,
              clients_data: List[Dict], test: Dict, task: str,
              batch_size: int, eval_batch: int, verbose: bool,
              backend: str = "sequential", mesh=None):
    from repro.core import round_program
    return round_program.run_program(model, base, cfg, fed, targets,
                                     public, clients_data, test, task,
                                     batch_size, eval_batch, verbose,
                                     backend=backend, mesh=mesh)
