"""Asynchronous, staleness-aware aggregation (``FedConfig(aggregation=
"async")``) — the second scenario axis the paper's comparison needs at
scale (cf. "Federated LLMs: Current Progress and Future Directions",
arXiv:2409.15723): real fleets never deliver every client's update in
lockstep, so the server must fold in *late* knowledge without stalling
the round clock.

Simulation model (FedAsync-style, deterministic under ``FedConfig.seed``):

- ``ParticipationSchedule`` gives every client a seeded per-job delay.
  A free client *starts* a job each round: it pulls the current global
  state, trains locally NOW (so its update reflects the global it saw),
  and the update goes in flight for ``delay`` rounds.
- At each round the server aggregates the updates that *arrive*, each
  weighted by its data weight times the polynomial staleness decay
  ``(1 + s)^-staleness_decay`` where ``s = arrival - start`` rounds.
  Updates staler than ``max_staleness`` are discarded (the client simply
  re-syncs).  The mass of clients that delivered nothing this round
  anchors the current global, so a lone stale straggler cannot yank the
  model.
- ``max_staleness == 0`` forces fully synchronous participation, which
  makes the async engine coincide with the sync engines *exactly* at
  ``lora_dropout == 0`` (tests/test_async_agg.py) — the knob
  interpolates between the paper-literal round and a realistic fleet.

The staleness treatment is uniform across the three frameworks — what
differs is the payload in flight: LoRA **params** for FedLLM, public-set
**logits** for KD-FedLLM, and **client-half adapters** for Split-FedLLM
(activations/grad traffic stays synchronous inside the training round:
the server's half is in the loop while a split client trains).

Both execution backends share this driver; only local execution differs
— the sequential executors below loop clients, the SPMD executors
(core/rounds_spmd.py) run the round's ready-set as per-rank bucketed
stacked programs.  Ledger bytes are therefore identical across backends
by construction, and heterogeneous ``client_ranks`` compose freely with
async (stale hetero updates harmonize through ``aggregate_hetero``).
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Dict, List

import jax
import numpy as np

from repro.core import kd as kd_mod
from repro.core import metrics as M
from repro.core import split as split_mod
from repro.core.fedavg import evaluate, fedavg, make_fns
from repro.core.heterogeneous import aggregate_hetero
from repro.data.loader import epoch_batches
from repro.peft import lora as lora_lib
from repro.privacy import dp as dp_mod
from repro.privacy.secure_agg import SecureAggSession


# --------------------------------------------------------------------------- #
# Participation schedule + staleness weights
# --------------------------------------------------------------------------- #
class ParticipationSchedule:
    """Deterministic per-client availability/delay model.

    Client speed is a per-client *trait*: a "slowness" drawn once from
    the master seed gives each client a Binomial(max_staleness + 1,
    slowness) delay per job — fast clients usually deliver in the round
    they start, slow clients lag several rounds and occasionally exceed
    ``max_staleness`` (the server discards those updates).  Per-client
    generators are consumed one draw per started job, so a fixed seed
    replays the identical schedule on any backend."""

    def __init__(self, n_clients: int, seed: int = 0,
                 max_staleness: int = 4):
        master = np.random.default_rng(seed)
        self.slowness = master.uniform(0.15, 0.85, n_clients)
        self.max_staleness = int(max_staleness)
        self._rngs = [np.random.default_rng((seed, 7919, ci))
                      for ci in range(n_clients)]

    def next_delay(self, ci: int) -> int:
        """Rounds until client ``ci``'s freshly started job delivers
        (0 = the same round it trains)."""
        if self.max_staleness <= 0:
            return 0
        return int(self._rngs[ci].binomial(self.max_staleness + 1,
                                           self.slowness[ci]))


def staleness_weight(staleness: int, decay: float) -> float:
    """Polynomial staleness decay (FedAsync): ``(1 + s)^-decay``."""
    return float((1.0 + staleness) ** (-decay))


@dataclasses.dataclass
class _Job:
    """One in-flight client update."""
    client: int
    start: int          # round the client pulled the global + trained
    arrival: int        # round the update lands on the server
    payload: object     # params / logits / client-half adapters


def _pop_arrivals(in_flight: Dict[int, _Job], rnd: int) -> List[_Job]:
    """Jobs delivering this round, in client visit order."""
    arrived = sorted((j for j in in_flight.values() if j.arrival == rnd),
                     key=lambda j: j.client)
    for j in arrived:
        del in_flight[j.client]
    return arrived


def stale_weighted_avg(global_tree, arrivals, total_weight: float, fed,
                       ranks: List[int]):
    """Staleness-weighted FedAvg of arrived parameter trees.

    ``arrivals`` is a list of ``(client, tree, staleness, data_weight)``
    already filtered to ``staleness <= max_staleness``.  The data weight
    of every client that delivered nothing this round anchors the
    current global tree, so the update is a convex combination that
    degenerates to plain (hetero-aware) FedAvg when everyone arrives
    fresh — the sync-equivalence property the tests pin down."""
    trees = [t for _, t, _, _ in arrivals]
    rks = [ranks[ci] for ci, _, _, _ in arrivals]
    ws = [w * staleness_weight(s, fed.staleness_decay)
          for _, _, s, w in arrivals]
    absent = total_weight - sum(w for _, _, _, w in arrivals)
    if absent > 0:
        trees = [global_tree] + trees
        rks = [fed.lora_rank] + rks
        ws = [absent] + ws
    if any(r != fed.lora_rank for r in rks):
        return aggregate_hetero(trees, rks, fed.lora_alpha, fed.lora_rank,
                                ws, fed.hetero_agg)
    return fedavg(trees, ws)


# --------------------------------------------------------------------------- #
# Entry point (core/rounds.run_federated dispatches here)
# --------------------------------------------------------------------------- #
def run_async(model, base, cfg, fed, targets, public: Dict,
              clients_data: List[Dict], test: Dict, task: str,
              batch_size: int, eval_batch: int, verbose: bool,
              backend: str = "sequential"):
    from repro.core.rounds import client_lora_ranks

    ranks = client_lora_ranks(fed, len(clients_data))
    if backend == "spmd":
        from repro.core import rounds_spmd
        make_exec = {"fedllm": rounds_spmd.spmd_fedllm_exec,
                     "kd": rounds_spmd.spmd_kd_exec,
                     "split": rounds_spmd.spmd_split_exec}[fed.framework]
    else:
        make_exec = {"fedllm": _seq_fedllm_exec, "kd": _seq_kd_exec,
                     "split": _seq_split_exec}[fed.framework]
    ex = make_exec(model, base, cfg, fed, targets, clients_data, public,
                   task, batch_size, eval_batch, ranks)
    driver = {"fedllm": _drive_fedllm, "kd": _drive_kd,
              "split": _drive_split}[fed.framework]
    return driver(ex, base, cfg, fed, clients_data, test, eval_batch,
                  verbose, ranks)


def _local_rng(fed, rnd: int, ci: int):
    """Per-(client, round) dropout RNG — both backends use the same
    stream in async mode, so seq/spmd agree bit-exactly at dropout 0 and
    draw equally valid (different) masks otherwise."""
    return jax.random.PRNGKey(fed.seed * 1013 + rnd * 131 + ci)


# --------------------------------------------------------------------------- #
# 1) FedLLM async (payload: LoRA params)
# --------------------------------------------------------------------------- #
def _drive_fedllm(ex, base, cfg, fed, clients_data, test, eval_batch,
                  verbose, ranks):
    from repro.core.rounds import (FedResult, make_accountant,
                                   round_epsilon)

    n_clients = len(clients_data)
    key = jax.random.PRNGKey(fed.seed + 1)
    global_lt = lora_lib.init_lora(key, base, ex.targets, fed.lora_rank,
                                   fed.lora_alpha)
    sched = ParticipationSchedule(n_clients, fed.seed + 17,
                                  fed.max_staleness)
    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    data_w = [len(d["tokens"]) for d in clients_data]
    total_w = float(sum(data_w))
    in_flight: Dict[int, _Job] = {}
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)
    releases = [0] * n_clients      # noisy uploads per client (epsilon)

    for rnd in range(fed.rounds):
        # every free client pulls the current global and starts a job;
        # this round's starters form one secure-agg masking cohort (the
        # payloads are created — and masked — now, even though they may
        # deliver rounds later)
        starters = [ci for ci in range(n_clients) if ci not in in_flight]
        secagg.begin_cohort(ledger, rnd, starters)
        jobs = []
        for ci in starters:
            lt = lora_lib.maybe_truncate_rank(global_lt, ranks[ci],
                                              fed.lora_rank)
            ledger.record(rnd, ci, "lora_params", M.DOWN, M.tree_bytes(lt))
            jobs.append((ci, lt))
        for (ci, _), (new_lt, n_tok) in zip(jobs, ex.train(jobs, rnd)):
            cost[ci].add_train(cfg, n_tok, lora_lib.n_params(new_lt))
            new_lt = dp_mod.privatize_tree(
                new_lt, dp_mod.noise_key(fed, rnd, ci), priv.noise_std)
            secagg.collect(rnd, ci, new_lt)
            releases[ci] += 1
            in_flight[ci] = _Job(ci, rnd, rnd + sched.next_delay(ci),
                                 new_lt)
        # fold in this round's arrivals, staleness-weighted; too-stale
        # masked uploads are dropped (their pairwise masks recovered
        # like any other absent cohort member's)
        arrivals, delivered = [], []
        for j in _pop_arrivals(in_flight, rnd):
            ledger.record(rnd, j.client, "lora_params", M.UP,
                          M.tree_bytes(j.payload))
            if priv.dp_enabled:
                ledger.record(rnd, j.client, "dp_meta", M.UP,
                              M.DP_META_BYTES)
            s = rnd - j.start
            if s <= fed.max_staleness:
                arrivals.append((j.client, j.payload, s, data_w[j.client]))
                delivered.append((j.start, j.client))
            else:
                secagg.discard(j.start, j.client)
        secagg.deliver(ledger, rnd, delivered)
        if arrivals:
            global_lt = stale_weighted_avg(global_lt, arrivals, total_w,
                                           fed, ranks)
        acc, loss = evaluate(ex.fns, base, global_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, max(releases))))
        if verbose:
            print(f"[fedllm/async] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f} arrived={len(arrivals)}")
    return FedResult(history, ledger, global_lt, [c.flops for c in cost])


def _seq_fedllm_exec(model, base, cfg, fed, targets, clients_data, public,
                     task, batch_size, eval_batch, ranks):
    fns = make_fns(model, fed, task)

    def train(jobs, rnd):
        out = []
        for ci, lt in jobs:
            opt = fns["opt_init"](lt)
            rng = _local_rng(fed, rnd, ci)
            n_tok = 0
            for ep in range(fed.local_epochs):
                for batch in epoch_batches(clients_data[ci], batch_size,
                                           seed=fed.seed * 997 + rnd + ep):
                    rng, sub = jax.random.split(rng)
                    jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                    lt, opt, _ = fns["train_step"](base, lt, opt, jb, sub)
                    n_tok += batch["tokens"].size
            out.append((lt, n_tok))
        return out

    return SimpleNamespace(fns=fns, targets=targets, train=train)


# --------------------------------------------------------------------------- #
# 2) KD-FedLLM async (payload: public-set logits)
# --------------------------------------------------------------------------- #
def _drive_kd(ex, base, cfg, fed, clients_data, test, eval_batch, verbose,
              ranks):
    from repro.core.rounds import (FedResult, make_accountant,
                                   round_epsilon)

    n_clients = len(clients_data)
    sched = ParticipationSchedule(n_clients, fed.seed + 17,
                                  fed.max_staleness)
    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    data_w = [len(d["tokens"]) for d in clients_data]
    pub_tok = ex.public["tokens"].size
    in_flight: Dict[int, _Job] = {}
    glob = None                        # latest global knowledge (b6)
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)
    releases = [0] * n_clients

    for rnd in range(fed.rounds):
        # free clients start a job: b1 local FT + b2/b3 knowledge (the
        # starters are the round's secure-agg masking cohort; the b3
        # logits are row-clipped + noised before compression)
        starters = [ci for ci in range(n_clients) if ci not in in_flight]
        secagg.begin_cohort(ledger, rnd, starters)
        for ci, (logits, n_tok) in zip(starters,
                                       ex.train_and_logits(starters, rnd)):
            logits = dp_mod.privatize_logits(
                logits, dp_mod.noise_key(fed, rnd, ci), fed)
            lg, wire = kd_mod.compress_for_wire(logits, fed)
            secagg.collect(rnd, ci, lg)
            releases[ci] += 1
            cost[ci].add_train(cfg, n_tok, ex.n_lora[ci])
            cost[ci].add_fwd(cfg, pub_tok)
            in_flight[ci] = _Job(ci, rnd, rnd + sched.next_delay(ci),
                                 (lg, wire))
        # arrivals: b4 staleness-weighted knowledge processing
        arrived = _pop_arrivals(in_flight, rnd)
        kept, ws, delivered = [], [], []
        for j in arrived:
            ledger.record(rnd, j.client, "logits", M.UP, j.payload[1])
            if priv.dp_enabled:
                ledger.record(rnd, j.client, "dp_meta", M.UP,
                              M.DP_META_BYTES)
            s = rnd - j.start
            if s <= fed.max_staleness:
                kept.append(j.payload[0])
                ws.append(data_w[j.client]
                          * staleness_weight(s, fed.staleness_decay))
                delivered.append((j.start, j.client))
            else:
                secagg.discard(j.start, j.client)
        secagg.deliver(ledger, rnd, delivered)
        if kept:
            teacher = kd_mod.aggregate_knowledge(kept, ws)
            # b5: distill the (possibly stale) knowledge into the server
            ex.server_lt, ex.server_opt, _ = kd_mod.distill(
                ex.fns, base, ex.server_lt, ex.server_opt, ex.public,
                teacher, fed.kd_epochs, eval_batch, seed=fed.seed + rnd)
            glob = kd_mod.client_logits(ex.fns, base, ex.server_lt,
                                        ex.public, eval_batch)
        # b6-b8: delivering clients re-sync against the latest knowledge
        if arrived and glob is not None:
            glob_wire = kd_mod.logit_wire_bytes(glob.shape, fed)
            cis = [j.client for j in arrived]
            for ci in cis:
                ledger.record(rnd, ci, "logits", M.DOWN, glob_wire)
                cost[ci].add_train(cfg, pub_tok * fed.kd_epochs,
                                   ex.n_lora[ci])
            ex.distill(cis, glob, rnd)
        acc, loss = evaluate(ex.fns, base, ex.server_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, max(releases))))
        if verbose:
            print(f"[kd/async] round {rnd}: acc={acc:.4f} loss={loss:.4f} "
                  f"arrived={len(arrived)}")
    return FedResult(history, ledger, ex.server_lt,
                     [c.flops for c in cost])


def make_kd_state(model, base, fed, targets, ranks, public,
                  task: str):
    """Client/server initialization shared by the sequential and SPMD
    KD async executors — one definition, so the backends can never
    drift on the bit-exact ``fold_in(key, ci)`` init streams (the same
    streams the sync engines use)."""
    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 2)
    lts = [lora_lib.init_lora(jax.random.fold_in(key, ci), base, targets,
                              ranks[ci], fed.lora_alpha)
           for ci in range(len(ranks))]
    server_lt = lora_lib.init_lora(jax.random.fold_in(key, 999), base,
                                   targets, fed.lora_rank, fed.lora_alpha)
    return SimpleNamespace(fns=fns, targets=targets, public=public,
                           lts=lts, opts=[fns["opt_init"](lt) for lt in lts],
                           server_lt=server_lt,
                           server_opt=fns["opt_init"](server_lt),
                           n_lora=[lora_lib.n_params(lt) for lt in lts])


def make_split_state(model, base, cfg, fed, targets, clients_data,
                     task: str, batch_size: int):
    """Split-half initialization shared by the sequential and SPMD
    Split async executors (same ``PRNGKey(seed + 3)`` stream as the
    sync engines)."""
    fns = make_fns(model, fed, task)
    sfns = split_mod.make_split_fns(model, fed, task)
    L = sfns["n_client_groups"]
    key = jax.random.PRNGKey(fed.seed + 3)
    full_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                 fed.lora_alpha)
    c_global, s_lt = split_mod.split_lora(full_lt, L)
    base_c, base_s = split_mod.split_base(base, L, cfg.is_encoder_decoder)
    return SimpleNamespace(
        fns=fns, sfns=sfns, targets=targets, c_global=c_global, s_lt=s_lt,
        s_opt=sfns["opt_init"](s_lt), base_c=base_c, base_s=base_s,
        frac_client=L / max(sfns["n_groups"], 1),
        label_bytes=_label_bytes(clients_data, batch_size))


def _seq_kd_exec(model, base, cfg, fed, targets, clients_data, public,
                 task, batch_size, eval_batch, ranks):
    ex = make_kd_state(model, base, fed, targets, ranks, public, task)
    fns, lts, opts = ex.fns, ex.lts, ex.opts

    def train_and_logits(cis, rnd):
        out = []
        for ci in cis:
            lt, opt = lts[ci], opts[ci]
            rng = _local_rng(fed, rnd, ci)
            n_tok = 0
            for ep in range(fed.local_epochs):
                for batch in epoch_batches(clients_data[ci], batch_size,
                                           seed=fed.seed * 991 + rnd + ep):
                    rng, sub = jax.random.split(rng)
                    jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                    lt, opt, _ = fns["train_step"](base, lt, opt, jb, sub)
                    n_tok += batch["tokens"].size
            lts[ci], opts[ci] = lt, opt
            out.append((kd_mod.client_logits(fns, base, lt, public,
                                             eval_batch), n_tok))
        return out

    def distill(cis, glob, rnd):
        for ci in cis:
            lts[ci], opts[ci], _ = kd_mod.distill(
                fns, base, lts[ci], opts[ci], public, glob, fed.kd_epochs,
                eval_batch, seed=fed.seed + 31 * rnd + ci)

    ex.train_and_logits, ex.distill = train_and_logits, distill
    return ex


# --------------------------------------------------------------------------- #
# 3) Split-FedLLM async (payload: client-half adapters)
# --------------------------------------------------------------------------- #
def _drive_split(ex, base, cfg, fed, clients_data, test, eval_batch,
                 verbose, ranks):
    from repro.core.rounds import (FedResult, make_accountant,
                                   round_epsilon)

    n_clients = len(clients_data)
    sched = ParticipationSchedule(n_clients, fed.seed + 17,
                                  fed.max_staleness)
    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    data_w = [len(d["tokens"]) for d in clients_data]
    total_w = float(sum(data_w))
    in_flight: Dict[int, _Job] = {}
    c_global = ex.c_global
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)
    releases = [0] * n_clients      # per-client c2 noise events

    for rnd in range(fed.rounds):
        # free clients run a split-training job NOW (the server half is
        # in the activation loop, so it updates synchronously — every
        # boundary activation is clipped + noised inside the step); only
        # the cc1 client-half adapter upload goes in flight, masked
        # against this round's starter cohort
        starters = [ci for ci in range(n_clients) if ci not in in_flight]
        secagg.begin_cohort(ledger, rnd, starters)
        jobs = []
        for ci in starters:
            c_init = lora_lib.maybe_truncate_rank(c_global, ranks[ci],
                                                  fed.lora_rank)
            ledger.record(rnd, ci, "lora_params", M.DOWN,
                          M.tree_bytes(c_init))                      # cc3
            jobs.append((ci, c_init))
        for (ci, _), (c_lt, n_tok, n_steps, shape) in zip(
                jobs, ex.train(jobs, rnd)):
            if n_steps:          # a sub-batch-size client trains 0 steps
                up, down = ex.sfns["wire_bytes_per_batch"](shape)
                lbl = ex.label_bytes
                for _ in range(n_steps):
                    ledger.record(rnd, ci, "activations", M.UP,
                                  up + lbl)                            # c2
                    ledger.record(rnd, ci, "act_grads", M.DOWN, down)  # c4
                    if priv.dp_enabled:
                        ledger.record(rnd, ci, "dp_meta", M.UP,
                                      M.DP_META_BYTES)
            releases[ci] += n_steps
            cost[ci].add_train(cfg, n_tok, lora_lib.n_params(c_lt),
                               frac_layers=ex.frac_client)
            secagg.collect(rnd, ci, c_lt)
            in_flight[ci] = _Job(ci, rnd, rnd + sched.next_delay(ci), c_lt)
        # arrivals: staleness-weighted FedAvg of the client halves (cc2)
        arrivals, delivered = [], []
        for j in _pop_arrivals(in_flight, rnd):
            ledger.record(rnd, j.client, "lora_params", M.UP,
                          M.tree_bytes(j.payload))                   # cc1
            s = rnd - j.start
            if s <= fed.max_staleness:
                arrivals.append((j.client, j.payload, s, data_w[j.client]))
                delivered.append((j.start, j.client))
            else:
                secagg.discard(j.start, j.client)
        secagg.deliver(ledger, rnd, delivered)
        if arrivals:
            c_global = stale_weighted_avg(c_global, arrivals, total_w,
                                          fed, ranks)
        joined = split_mod.join_lora(c_global, ex.s_lt)
        acc, loss = evaluate(ex.fns, base, joined, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, max(releases))))
        if verbose:
            print(f"[split/async] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f} arrived={len(arrivals)}")
    return FedResult(history, ledger, joined, [c.flops for c in cost])


def _seq_split_exec(model, base, cfg, fed, targets, clients_data, public,
                    task, batch_size, eval_batch, ranks):
    ex = make_split_state(model, base, cfg, fed, targets, clients_data,
                          task, batch_size)
    sfns, base_c, base_s = ex.sfns, ex.base_c, ex.base_s

    def train(jobs, rnd):
        out = []
        for ci, c_init in jobs:
            c_lt, c_opt = c_init, sfns["opt_init"](c_init)
            rng = _local_rng(fed, rnd, ci)
            n_tok, n_steps, shape = 0, 0, None
            for batch in epoch_batches(clients_data[ci], batch_size,
                                       seed=fed.seed * 983 + rnd):
                rng, sub = jax.random.split(rng)
                jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                nkey = dp_mod.noise_key(fed, rnd, ci, n_steps) \
                    if fed.privacy.dp_enabled else None
                c_lt, ex.s_lt, c_opt, ex.s_opt, _ = \
                    sfns["split_train_step"](base_c, base_s, c_lt, ex.s_lt,
                                             c_opt, ex.s_opt, jb, sub, nkey)
                n_tok += batch["tokens"].size
                n_steps += 1
                shape = batch["tokens"].shape
            out.append((c_lt, n_tok, n_steps, shape))
        return out

    ex.train = train
    return ex


def _label_bytes(clients_data, batch_size: int) -> int:
    """c2 piggybacks the labels with the boundary activations."""
    return batch_size * 4 if "labels" in clients_data[0] else 0
