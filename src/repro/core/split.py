"""Split-FedLLMs — activation-based updates (paper SSII.C):

    c1 client: forward through the first layers on private data
    c2 client -> server: boundary activations (+ labels)
    c3 server: forward through remaining layers, loss, backprop
    c4 server -> client: activation gradients
    c5 client: backprop through its layers, update tunable params
    cc1-cc4 clients <-> server: LoRA FedAvg of the *client-side* params

Split points (DESIGN SS2): *inter* — a pattern-group boundary index
(initial groups on the client, the rest + head on the server); for
encoder-decoder archs the natural boundary client=encoder/server=decoder;
*intra* — inside a block (attention client-side, FFN server-side), for
homogeneous-attention archs.

Activation/gradient transfers optionally pass through int8/int4
straight-through quantization (paper SSIV.C.2, core/compression.py); wire
bytes are what the quantized payload costs.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, ModelConfig
from repro.core import compression, tasks
from repro.models import common, transformer
from repro.models.factory import Model
from repro.optim.api import make_optimizer
from repro.peft import lora as lora_lib


# --------------------------------------------------------------------------- #
# LoRA tree partitioning
# --------------------------------------------------------------------------- #
def split_lora(lt, n_client_groups: int):
    """(client_tree, server_tree) from a full-model LoRA tree."""
    client, server = {}, {}
    for k, v in lt.items():
        if k == "blocks":
            client[k] = jax.tree.map(lambda x: x[:n_client_groups], v)
            server[k] = jax.tree.map(lambda x: x[n_client_groups:], v)
        elif k == "encoder":
            client[k] = v
        else:
            server[k] = v
    return client, server


def join_lora(client, server):
    out = {}
    for k in set(client) | set(server):
        if k == "blocks" and k in client and k in server:
            out[k] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                client[k], server[k])
        elif k in client:
            out[k] = client[k]
        else:
            out[k] = server[k]
    return out


def split_base(base, n_client_groups: int, enc_dec: bool):
    """Slice the frozen base params at the split point."""
    if enc_dec:
        client = {k: v for k, v in base.items() if k == "encoder"}
        server = {k: v for k, v in base.items() if k != "encoder"}
        return client, server
    client = dict(base)
    client["blocks"] = jax.tree.map(lambda x: x[:n_client_groups],
                                    base["blocks"])
    client.pop("tail", None)
    client.pop("final_norm", None)
    client.pop("lm_head", None)
    server = dict(base)
    server["blocks"] = jax.tree.map(lambda x: x[n_client_groups:],
                                    base["blocks"])
    return client, server


# --------------------------------------------------------------------------- #
# Split train step
# --------------------------------------------------------------------------- #
def make_split_fns(model: Model, fed: FedConfig,
                   task: str = "classification"):
    cfg = model.cfg
    task_loss = tasks.get_loss_fn(task)
    opt_init, opt_update = make_optimizer(fed.optimizer)
    n_groups = transformer.n_groups_of(cfg)
    L = min(max(fed.split_layer, 0), n_groups - 1) if not \
        cfg.is_encoder_decoder else 0
    qbits = fed.activation_quant_bits
    priv = fed.privacy

    def _bind(base, lt, rng=None):
        # rank read off the tree: heterogeneous client halves arrive
        # truncated to the client's own rank and need alpha/r_c scaling
        rank = lora_lib.tree_rank(lt, fed.lora_rank)
        return lora_lib.bind(base, lt, fed.lora_alpha, rank,
                             dropout_mask_rng=rng, dropout=fed.lora_dropout)

    def _maybe_q(x):
        if qbits:
            y, _ = compression.quant_roundtrip(x, qbits)
            return y
        return x

    def split_step(base_c, base_s, c_lt, s_lt, c_opt, s_opt, batch, rng,
                   nkey=None):
        """One split training step.  ``nkey`` is the per-(client, round,
        step) privacy noise key (privacy/dp.noise_key) consumed by the
        c2 activation mechanism when ``PrivacyConfig.dp_clip > 0``:
        each boundary token row is L2-clipped to dp_clip and carries
        Gaussian noise of stddev sigma*C *before* quantization — the
        transmitted payload is the protected one.  The c4 gradient
        download (server -> client) is not part of this threat surface.
        Noise keys come from a dedicated fold_in stream, never the
        dropout RNG, so both backends draw identical noise."""
        tokens = batch["tokens"]

        if cfg.is_encoder_decoder:
            from repro.models import encdec

            def client_fwd(cl):
                bound = _bind(base_c, cl, rng)
                return encdec.encode({"encoder": bound["encoder"]}, cfg,
                                     batch["enc_embeds"])

            def server_fwd(sl, h_in):
                bound = _bind(base_s, sl, rng)
                logits, aux = encdec.decode_given_enc(bound, cfg, tokens,
                                                      h_in)
                loss, _ = task_loss(logits, batch)
                return loss + aux
        else:
            B, S = tokens.shape
            img = batch.get("img_embeds")

            def client_fwd(cl):
                bound = _bind(base_c, cl, rng)
                h, positions = transformer.embed_tokens(
                    bound, cfg, tokens, img)
                h, _ = transformer.forward_groups(bound, cfg, h, positions,
                                                  0, L)
                return h

            def server_fwd(sl, h_in):
                bound = _bind(base_s, sl, rng)
                Sp = h_in.shape[1]
                positions = jnp.broadcast_to(
                    jnp.arange(Sp, dtype=jnp.int32)[None], (B, Sp))
                h, aux = transformer.forward_groups(
                    bound, cfg, h_in, positions, 0, n_groups - L,
                    include_tail=True)
                h = common.apply_norm(cfg.norm, bound["final_norm"], h)
                logits = transformer.lm_logits(bound, cfg, h)
                loss, _ = task_loss(logits, batch)
                return loss + aux

        # c1/c2: client forward, activations "up" (privatized, quantized)
        h, client_vjp = jax.vjp(client_fwd, c_lt)
        if priv.dp_enabled:
            from repro.privacy import dp as dp_mod
            h = dp_mod.privatize_rows(h, nkey, fed)
        h_wire = _maybe_q(h)
        # c3: server forward/backward
        loss, (s_grads, h_grad) = jax.value_and_grad(
            server_fwd, argnums=(0, 1))(s_lt, h_wire)
        # c4/c5: activation grads "down" (quantized), client backward
        (c_grads,) = client_vjp(_maybe_q(h_grad))
        new_c, c_opt2 = opt_update(c_grads, c_opt, c_lt, fed.lr)
        new_s, s_opt2 = opt_update(s_grads, s_opt, s_lt, fed.lr)
        return new_c, new_s, c_opt2, s_opt2, loss

    jitted_split_step = jax.jit(split_step)

    def split_train_step(*args, **kwargs):
        # same depth contract as fedavg.make_fns: the whole step body —
        # both sub-model halves and the quantized boundary — traces
        # under the model's kernel-policy scope even when called
        # directly rather than through core/rounds.run_federated.
        from repro.kernels import ops as kernel_ops
        with kernel_ops.policy_scope(cfg.kernel_policy):
            return jitted_split_step(*args, **kwargs)

    def wire_bytes_per_batch(batch_shape: Tuple[int, int]) -> Tuple[int, int]:
        """(activation_up, grad_down) bytes for one batch (c2/c4).

        int4 payloads are nibble-packed (core/compression.pack_int4):
        two values per byte, per-row ceil — the exact transmittable
        size, not the old ``bits // 8 == 0`` undercount."""
        B, S = batch_shape
        if cfg.is_encoder_decoder:
            S = cfg.encoder_seq_len
        rows, d = B * S, cfg.d_model
        if qbits == 4:
            payload = rows * ((d + 1) // 2)
        elif qbits:
            payload = rows * d * qbits // 8
        else:
            payload = rows * d * 4
        scale = rows * 4 if qbits else 0
        return payload + scale, payload + scale

    return {"split_train_step": split_train_step, "split_step": split_step,
            "opt_init": opt_init, "n_client_groups": L,
            "wire_bytes_per_batch": wire_bytes_per_batch,
            "n_groups": n_groups}


# --------------------------------------------------------------------------- #
# Dynamic split-point selection (SSIV.C.1 — beyond-paper feature)
# --------------------------------------------------------------------------- #
def choose_split_point(cfg: ModelConfig, client_flops_budget: float,
                       n_tokens_per_round: int) -> int:
    """Largest client-side group count whose per-round training FLOPs fit
    the client budget (resource-aware workload distribution)."""
    n_groups = max(1, cfg.n_layers // max(len(cfg.layer_pattern or (1,)), 1))
    per_group = 6.0 * (cfg.active_param_count() / max(cfg.n_layers, 1)) \
        * len(cfg.layer_pattern or (1,)) * n_tokens_per_round
    if per_group <= 0:
        return 1
    k = int(client_flops_budget // per_group)
    return int(min(max(k, 1), n_groups - 1))
