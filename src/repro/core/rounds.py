"""Federated round engine — drives any of the three paper frameworks over
one shared substrate and records the paper's metrics (accuracy, comm
bytes, client FLOPs) per round.

    result = run_federated(cfg, fed, model_seed=0, data=..., task=...)

``result.history`` is a list of RoundMetrics; ``result.ledger`` has every
wire transfer; Fig. 3 / Fig. 4 / Table I benchmarks read from these.

Execution backends (``FedConfig.backend``): every framework dispatches
to either the ``sequential`` backend in this module (python loop over
clients, one jitted step per batch — the paper-literal reference) or the
``spmd`` backend (clients stacked on a leading axis, one jitted program
per round; core/rounds_spmd.py + core/fed_spmd.py).  Both backends
produce the same ledger bytes exactly and the same accuracy within fp32
tolerance (tests/test_backend_parity.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, ModelConfig
from repro.core import kd as kd_mod
from repro.core import metrics as M
from repro.core import split as split_mod
from repro.core.fedavg import evaluate, fedavg, make_fns
from repro.core.heterogeneous import aggregate_hetero
from repro.data import partition as part_mod
from repro.data.loader import epoch_batches
from repro.models.factory import build_model
from repro.peft import lora as lora_lib


@dataclasses.dataclass
class FedResult:
    history: List[M.RoundMetrics]
    ledger: M.CommLedger
    final_lora: Dict
    client_flops: List[float]

    @property
    def final_accuracy(self) -> float:
        return self.history[-1].accuracy if self.history else 0.0


def _to_jax(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


def make_accountant(fed: FedConfig):
    """RDP accountant for the run, or None when DP is off entirely.

    A clipping-only run (dp_clip > 0, noise 0) gets an accountant whose
    epsilon is ``inf`` — the mechanism is active but offers no
    (eps, delta) guarantee, and reporting 0.0 would claim the strongest
    one instead."""
    if not fed.privacy.dp_enabled:
        return None
    from repro.privacy.accountant import GaussianAccountant
    return GaussianAccountant(fed.privacy.dp_noise_multiplier,
                              fed.privacy.dp_delta)


def round_epsilon(acct, releases: int) -> float:
    """eps at the configured dp_delta after ``releases`` noisy uploads
    per client; 0.0 when DP is not enabled (no accounting, no claim),
    inf when clipping runs without noise."""
    return acct.epsilon(releases) if acct is not None else 0.0


def client_lora_ranks(fed: FedConfig, n_clients: int) -> List[int]:
    """Per-client LoRA ranks, validated against the client count."""
    if not fed.client_ranks:
        return [fed.lora_rank] * n_clients
    if len(fed.client_ranks) != n_clients:
        raise ValueError(
            f"client_ranks has {len(fed.client_ranks)} entries for "
            f"{n_clients} clients")
    if any(r < 1 or r > fed.lora_rank for r in fed.client_ranks):
        raise ValueError(
            f"client_ranks must lie in [1, lora_rank={fed.lora_rank}] "
            f"(got {fed.client_ranks}); weak clients truncate the global "
            "rank, they never exceed it")
    return list(fed.client_ranks)


def run_federated(cfg: ModelConfig, fed: FedConfig, public: Dict,
                  clients_data: List[Dict], test: Dict,
                  task: str = "classification", batch_size: int = 16,
                  eval_batch: int = 64, verbose: bool = False) -> FedResult:
    if fed.framework not in ("fedllm", "kd", "split"):
        raise ValueError(f"unknown framework {fed.framework!r}")
    backend = getattr(fed, "backend", "sequential") or "sequential"
    if backend not in ("sequential", "spmd"):
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'sequential' or 'spmd')")
    if fed.aggregation not in ("sync", "async"):
        raise ValueError(f"unknown aggregation {fed.aggregation!r} "
                         "(expected 'sync' or 'async')")
    if fed.privacy.dp_noise_multiplier > 0.0 and fed.privacy.dp_clip <= 0.0:
        raise ValueError(
            "privacy.dp_noise_multiplier > 0 requires privacy.dp_clip > 0 "
            "(the noise stddev is sigma * clip; an unclipped release has "
            "unbounded sensitivity and no (eps, delta) guarantee)")
    client_lora_ranks(fed, len(clients_data))   # validate early
    model = build_model(cfg)
    key = jax.random.PRNGKey(fed.seed)
    base = model.init(key)
    targets = fed.lora_targets or lora_lib.default_targets(cfg)

    # Resolve ModelConfig.kernel_policy for every trace in the run: both
    # execution backends and all three frameworks train through the fused
    # Pallas fwd+bwd kernels when the policy selects them.
    from repro.kernels import ops as kernel_ops
    with kernel_ops.policy_scope(cfg.kernel_policy):
        if fed.aggregation == "async":
            from repro.core import async_agg   # lazy: avoids import cycle
            return async_agg.run_async(model, base, cfg, fed, targets,
                                       public, clients_data, test, task,
                                       batch_size, eval_batch, verbose,
                                       backend)
        if backend == "spmd":
            from repro.core import rounds_spmd  # lazy: avoids import cycle
            return rounds_spmd.run_spmd(model, base, cfg, fed, targets,
                                        public, clients_data, test, task,
                                        batch_size, eval_batch, verbose)
        if fed.framework == "fedllm":
            return _run_fedllm(model, base, cfg, fed, targets, clients_data,
                               test, task, batch_size, eval_batch, verbose)
        if fed.framework == "kd":
            return _run_kd(model, base, cfg, fed, targets, public,
                           clients_data, test, task, batch_size, eval_batch,
                           verbose)
        return _run_split(model, base, cfg, fed, targets, clients_data,
                          test, task, batch_size, eval_batch, verbose)


# --------------------------------------------------------------------------- #
# 1) FedLLMs (SSII.A)
# --------------------------------------------------------------------------- #
def _run_fedllm(model, base, cfg, fed, targets, clients_data, test, task,
                batch_size, eval_batch, verbose):
    from repro.privacy import dp as dp_mod
    from repro.privacy.secure_agg import SecureAggSession

    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 1)
    n_clients = len(clients_data)
    ranks = client_lora_ranks(fed, n_clients)
    hetero = len(set(ranks)) > 1
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)

    global_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                   fed.lora_alpha)
    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    n_lora = lora_lib.n_params(global_lt)

    for rnd in range(fed.rounds):
        # the sync masking cohort is every client, every round
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        locals_, weights = [], []
        for ci, data in enumerate(clients_data):
            # a1: distribute global params (truncate rank for weak clients)
            lt = lora_lib.maybe_truncate_rank(global_lt, ranks[ci],
                                              fed.lora_rank)
            ledger.record(rnd, ci, "lora_params", M.DOWN, M.tree_bytes(lt))
            # a2: local fine-tuning (per-example DP-SGD clipping inside
            # the shared train step when privacy.dp_clip > 0)
            opt = fns["opt_init"](lt)
            n_tok = 0
            for ep in range(fed.local_epochs):
                for batch in epoch_batches(data, batch_size,
                                           seed=fed.seed * 997 + rnd + ep):
                    key, sub = jax.random.split(key)
                    lt, opt, _ = fns["train_step"](base, lt, opt,
                                                   _to_jax(batch), sub)
                    n_tok += batch["tokens"].size
            cost[ci].add_train(cfg, n_tok, lora_lib.n_params(lt))
            # a3: upload — seeded Gaussian noise on the payload, then
            # pairwise secure-agg masks over the (noisy) upload
            lt = dp_mod.privatize_tree(lt, dp_mod.noise_key(fed, rnd, ci),
                                       priv.noise_std)
            ledger.record(rnd, ci, "lora_params", M.UP, M.tree_bytes(lt))
            if priv.dp_enabled:
                ledger.record(rnd, ci, "dp_meta", M.UP, M.DP_META_BYTES)
            secagg.collect(rnd, ci, lt)
            locals_.append(lt)
            weights.append(len(data["tokens"]))
        # a4: aggregate (the masked sum cancels exactly — verified)
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        if hetero:
            global_lt = aggregate_hetero(locals_, ranks, fed.lora_alpha,
                                         fed.lora_rank, weights,
                                         fed.hetero_agg)
        else:
            global_lt = fedavg(locals_, weights)
        acc, loss = evaluate(fns, base, global_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss,
            ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, rnd + 1)))
        if verbose:
            print(f"[fedllm] round {rnd}: acc={acc:.4f} loss={loss:.4f}")
    return FedResult(history, ledger, global_lt, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 2) KD-FedLLMs (SSII.B)
# --------------------------------------------------------------------------- #
def _run_kd(model, base, cfg, fed, targets, public, clients_data, test,
            task, batch_size, eval_batch, verbose):
    from repro.privacy import dp as dp_mod
    from repro.privacy.secure_agg import SecureAggSession

    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 2)
    n_clients = len(clients_data)
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)
    # Heterogeneous ranks are KD's native habitat (paper SSIII.A): params
    # never cross the wire, so each client simply trains at its own rank
    # and the exchanged knowledge stays rank-agnostic.
    ranks = client_lora_ranks(fed, n_clients)

    client_lts = [lora_lib.init_lora(jax.random.fold_in(key, ci), base,
                                     targets, ranks[ci], fed.lora_alpha)
                  for ci in range(n_clients)]
    client_opts = [fns["opt_init"](lt) for lt in client_lts]
    server_lt = lora_lib.init_lora(jax.random.fold_in(key, 999), base,
                                   targets, fed.lora_rank, fed.lora_alpha)
    server_opt = fns["opt_init"](server_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    pub_tok = public["tokens"].size

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        uploaded = []
        weights = []
        for ci, data in enumerate(clients_data):
            lt, opt = client_lts[ci], client_opts[ci]
            # b1: local fine-tuning (params never leave the client;
            # per-example DP-SGD clipping inside the shared train step)
            n_tok = 0
            for ep in range(fed.local_epochs):
                for batch in epoch_batches(data, batch_size,
                                           seed=fed.seed * 991 + rnd + ep):
                    key, sub = jax.random.split(key)
                    lt, opt, _ = fns["train_step"](base, lt, opt,
                                                   _to_jax(batch), sub)
                    n_tok += batch["tokens"].size
            cost[ci].add_train(cfg, n_tok, lora_lib.n_params(lt))
            # b2: logits on the public dataset
            logits = kd_mod.client_logits(fns, base, lt, public, eval_batch)
            cost[ci].add_fwd(cfg, pub_tok)
            # b3: upload — row-clipped noisy logits first (the KD threat
            # surface), composing with the SSIV.B.2 compression
            logits = dp_mod.privatize_logits(
                logits, dp_mod.noise_key(fed, rnd, ci), fed)
            logits, wire = kd_mod.compress_for_wire(logits, fed)
            ledger.record(rnd, ci, "logits", M.UP, wire)
            if priv.dp_enabled:
                ledger.record(rnd, ci, "dp_meta", M.UP, M.DP_META_BYTES)
            secagg.collect(rnd, ci, logits)
            uploaded.append(logits)
            weights.append(len(data["tokens"]))
            client_lts[ci], client_opts[ci] = lt, opt
        # b4: knowledge processing (masked sum cancels exactly — verified)
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        teacher = kd_mod.aggregate_knowledge(uploaded, weights)
        # b5: server-side distillation into the global model
        server_lt, server_opt, _ = kd_mod.distill(
            fns, base, server_lt, server_opt, public, teacher,
            fed.kd_epochs, eval_batch, seed=fed.seed + rnd)
        # b6/b7: global logits back to clients (wire size is arithmetic —
        # no compression pipeline runs just to be discarded)
        glob = kd_mod.client_logits(fns, base, server_lt, public, eval_batch)
        glob_wire = kd_mod.logit_wire_bytes(glob.shape, fed)
        for ci in range(n_clients):
            ledger.record(rnd, ci, "logits", M.DOWN, glob_wire)
        # b8: client-side KD
        for ci in range(n_clients):
            client_lts[ci], client_opts[ci], _ = kd_mod.distill(
                fns, base, client_lts[ci], client_opts[ci], public, glob,
                fed.kd_epochs, eval_batch, seed=fed.seed + 31 * rnd + ci)
            # KD training pass over the public set
            cost[ci].add_train(cfg, pub_tok * fed.kd_epochs,
                               lora_lib.n_params(client_lts[ci]))
        acc, loss = evaluate(fns, base, server_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, rnd + 1)))
        if verbose:
            print(f"[kd] round {rnd}: acc={acc:.4f} loss={loss:.4f}")
    return FedResult(history, ledger, server_lt,
                     [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 3) Split-FedLLMs (SSII.C)
# --------------------------------------------------------------------------- #
def _run_split(model, base, cfg, fed, targets, clients_data, test, task,
               batch_size, eval_batch, verbose):
    from repro.privacy import dp as dp_mod
    from repro.privacy.secure_agg import SecureAggSession

    fns = make_fns(model, fed, task)           # for eval on the full model
    sfns = split_mod.make_split_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 3)
    n_clients = len(clients_data)
    ranks = client_lora_ranks(fed, n_clients)
    hetero = len(set(ranks)) > 1
    L = sfns["n_client_groups"]
    n_groups = sfns["n_groups"]
    frac_client = L / max(n_groups, 1)
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)
    releases = 0            # per-client c2 noise events (for epsilon)

    full_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                 fed.lora_alpha)
    c_global, s_lt = split_mod.split_lora(full_lt, L)
    base_c, base_s = split_mod.split_base(base, L, cfg.is_encoder_decoder)
    s_opt = sfns["opt_init"](s_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        locals_, weights = [], []
        max_steps = 0
        for ci, data in enumerate(clients_data):
            # cc3: distribute the global client half (truncated for weak
            # clients — only the *client-side* adapters are heterogeneous;
            # the server half never leaves the server)
            c_lt = lora_lib.maybe_truncate_rank(c_global, ranks[ci],
                                                fed.lora_rank)
            ledger.record(rnd, ci, "lora_params", M.DOWN,
                          M.tree_bytes(c_lt))                      # cc3
            c_opt = sfns["opt_init"](c_lt)
            n_tok, step = 0, 0
            for batch in epoch_batches(data, batch_size,
                                       seed=fed.seed * 983 + rnd):
                up, down = sfns["wire_bytes_per_batch"](
                    batch["tokens"].shape)
                ledger.record(rnd, ci, "activations", M.UP,
                              up + batch["labels"].size * 4)        # c2
                ledger.record(rnd, ci, "act_grads", M.DOWN, down)   # c4
                if priv.dp_enabled:
                    ledger.record(rnd, ci, "dp_meta", M.UP,
                                  M.DP_META_BYTES)
                key, sub = jax.random.split(key)
                nkey = dp_mod.noise_key(fed, rnd, ci, step) \
                    if priv.dp_enabled else None
                c_lt, s_lt, c_opt, s_opt, _ = sfns["split_train_step"](
                    base_c, base_s, c_lt, s_lt, c_opt, s_opt,
                    _to_jax(batch), sub, nkey)
                n_tok += batch["tokens"].size
                step += 1
            max_steps = max(max_steps, step)
            cost[ci].add_train(cfg, n_tok, lora_lib.n_params(c_lt),
                               frac_layers=frac_client)
            ledger.record(rnd, ci, "lora_params", M.UP,
                          M.tree_bytes(c_lt))                       # cc1
            secagg.collect(rnd, ci, c_lt)
            locals_.append(c_lt)
            weights.append(len(data["tokens"]))
        releases += max_steps
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        if hetero:                                                  # cc2
            c_global = aggregate_hetero(locals_, ranks, fed.lora_alpha,
                                        fed.lora_rank, weights,
                                        fed.hetero_agg)
        else:
            c_global = fedavg(locals_, weights)
        joined = split_mod.join_lora(c_global, s_lt)
        acc, loss = evaluate(fns, base, joined, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, releases)))
        if verbose:
            print(f"[split] round {rnd}: acc={acc:.4f} loss={loss:.4f}")
    return FedResult(history, ledger, joined, [c.flops for c in cost])
