"""Federated round engine — the public entry point that drives any of
the three paper frameworks over one shared substrate and records the
paper's metrics (accuracy, comm bytes, client FLOPs) per round.

    result = run_federated(cfg, fed, public, clients_data, test, ...)

``result.history`` is a list of RoundMetrics; ``result.ledger`` has every
wire transfer; Fig. 3 / Fig. 4 / Table I benchmarks read from these.

Since the RoundProgram refactor this module is a thin adapter: it
validates the config, builds the model, and hands off to the composable
pipeline in core/round_program.py, which runs every combination of

    framework (fedllm | kd | split)
    x backend (``FedConfig.backend``: sequential | spmd)
    x aggregation (``FedConfig.aggregation``: sync | async)

through one driver over the canonical stages ``broadcast ->
local_update -> upload -> aggregate -> evaluate`` with privacy and
heterogeneous-rank handling applied as middleware.  Both backends
produce the same ledger bytes exactly and the same accuracy within fp32
tolerance (tests/test_backend_parity.py).

Pass ``mesh=`` (a jax mesh, e.g. launch/mesh.make_production_mesh) to
let the SPMD backend shard the stacked client axis over the mesh's
client axes with explicit NamedShardings (launch/sharding.py).
"""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.configs.base import FedConfig, ModelConfig
from repro.core.heterogeneous import normalize_ranks
from repro.core.round_program import (FedResult, make_accountant,  # noqa: F401
                                      round_epsilon, run_program)
from repro.models.factory import build_model
from repro.peft import lora as lora_lib


def client_lora_ranks(fed: FedConfig, n_clients: int) -> List[int]:
    """Per-client LoRA ranks, validated against the client count
    (core/heterogeneous.normalize_ranks is the single source of
    truth)."""
    return normalize_ranks(fed.client_ranks, n_clients, fed.lora_rank)


def run_federated(cfg: ModelConfig, fed: FedConfig, public: Dict,
                  clients_data: List[Dict], test: Dict,
                  task: str = "classification", batch_size: int = 16,
                  eval_batch: int = 64, verbose: bool = False,
                  mesh=None) -> FedResult:
    if fed.framework not in ("fedllm", "kd", "split"):
        raise ValueError(f"unknown framework {fed.framework!r}")
    backend = getattr(fed, "backend", "sequential") or "sequential"
    if backend not in ("sequential", "spmd"):
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'sequential' or 'spmd')")
    if fed.aggregation not in ("sync", "async"):
        raise ValueError(f"unknown aggregation {fed.aggregation!r} "
                         "(expected 'sync' or 'async')")
    if fed.privacy.dp_noise_multiplier > 0.0 and fed.privacy.dp_clip <= 0.0:
        raise ValueError(
            "privacy.dp_noise_multiplier > 0 requires privacy.dp_clip > 0 "
            "(the noise stddev is sigma * clip; an unclipped release has "
            "unbounded sensitivity and no (eps, delta) guarantee)")
    client_lora_ranks(fed, len(clients_data))   # validate early
    model = build_model(cfg)
    key = jax.random.PRNGKey(fed.seed)
    base = model.init(key)
    targets = fed.lora_targets or lora_lib.default_targets(cfg)

    # Resolve ModelConfig.kernel_policy for every trace in the run: both
    # execution backends and all three frameworks train through the fused
    # Pallas fwd+bwd kernels when the policy selects them.
    from repro.kernels import ops as kernel_ops
    with kernel_ops.policy_scope(cfg.kernel_policy):
        return run_program(model, base, cfg, fed, targets, public,
                           clients_data, test, task, batch_size,
                           eval_batch, verbose, backend=backend,
                           mesh=mesh)
