"""Federated round engine — the public entry point that drives any of
the three paper frameworks over one shared substrate and records the
paper's metrics (accuracy, comm bytes, client FLOPs) per round.

    result = run_federated(cfg, fed, public, clients, test, ...)

``clients`` is THE way to supply the fleet: a
``data/population.ClientPopulation`` (lazy — a million-virtual-client
``DirichletPopulation`` materializes shards per cohort, never the
fleet) or, via a deprecation shim, the old eager list of per-client
data dicts (wrapped through ``ClientPopulation.from_clients_data`` with
a ``DeprecationWarning``).

``result.history`` is a list of RoundMetrics; ``result.ledger`` has every
wire transfer; Fig. 3 / Fig. 4 / Table I benchmarks read from these.

Since the RoundProgram refactor this module is a thin adapter: it
validates the config, builds the model, and hands off to the composable
pipeline in core/round_program.py, which runs every combination of

    framework (fedllm | kd | split)
    x backend (``FedConfig.backend``: sequential | spmd | cohort)
    x aggregation (``FedConfig.aggregation``: sync | async)

through one driver over the canonical stages ``broadcast ->
local_update -> upload -> aggregate -> evaluate`` with privacy and
heterogeneous-rank handling applied as middleware.  All backends
produce the same ledger bytes exactly and the same accuracy within fp32
tolerance (tests/test_backend_parity.py, tests/test_population.py);
``cohort`` streams the round ``FedConfig.cohort_size`` clients at a
time so peak memory is one cohort.

Pass ``mesh=`` (a jax mesh, e.g. launch/mesh.make_production_mesh) to
let the SPMD/cohort backends shard the stacked client axis over the
mesh's client axes with explicit NamedShardings (launch/sharding.py);
on a multi-pod mesh the cohort backend also reports hierarchical
client->edge / edge->server wire accounting.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Union

import jax

from repro.configs.base import FedConfig, ModelConfig
from repro.core.heterogeneous import normalize_ranks
from repro.core.round_program import (FedResult, make_accountant,  # noqa: F401
                                      round_epsilon, run_program)
from repro.data.population import ClientPopulation
from repro.models.factory import build_model
from repro.peft import lora as lora_lib


def client_lora_ranks(fed: FedConfig, n_clients: int) -> List[int]:
    """Per-client LoRA ranks, validated against the client count
    (core/heterogeneous.normalize_ranks is the single source of
    truth)."""
    return normalize_ranks(fed.client_ranks, n_clients, fed.lora_rank)


def _normalize_clients(clients, clients_data) -> ClientPopulation:
    """The ``clients`` argument shim: populations pass through; eager
    lists (including the legacy ``clients_data=`` keyword) keep working
    for one release behind a DeprecationWarning."""
    if clients_data is not None:
        if clients is not None:
            raise TypeError("pass either clients= or the legacy "
                            "clients_data=, not both")
        clients = clients_data
    if clients is None:
        raise TypeError("run_federated() missing required argument: "
                        "'clients'")
    if isinstance(clients, ClientPopulation):
        return clients
    warnings.warn(
        "passing an eager list of client dicts to run_federated() is "
        "deprecated; pass a data/population.ClientPopulation (use "
        "ClientPopulation.from_clients_data(list) to wrap an existing "
        "list)", DeprecationWarning, stacklevel=3)
    return ClientPopulation.from_clients_data(clients)


def run_federated(cfg: ModelConfig, fed: FedConfig, public: Dict,
                  clients: Union[ClientPopulation, List[Dict]] = None,
                  test: Dict = None,
                  task: str = "classification", batch_size: int = 16,
                  eval_batch: int = 64, verbose: bool = False,
                  mesh=None, clients_data: List[Dict] = None,
                  checkpoint_every: int = 0, checkpoint_dir: str = None,
                  resume_from: str = None) -> FedResult:
    clients = _normalize_clients(clients, clients_data)
    if test is None:
        raise TypeError("run_federated() missing required argument: "
                        "'test'")
    if fed.framework not in ("fedllm", "kd", "split"):
        raise ValueError(f"unknown framework {fed.framework!r}")
    backend = getattr(fed, "backend", "sequential") or "sequential"
    if backend not in ("sequential", "spmd", "cohort"):
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'sequential', 'spmd' or 'cohort')")
    if fed.aggregation not in ("sync", "async"):
        raise ValueError(f"unknown aggregation {fed.aggregation!r} "
                         "(expected 'sync' or 'async')")
    if fed.n_virtual_clients and fed.n_virtual_clients != len(clients):
        raise ValueError(
            f"FedConfig.n_virtual_clients={fed.n_virtual_clients} does "
            f"not match the supplied population ({len(clients)} clients)")
    if fed.privacy.dp_noise_multiplier > 0.0 and fed.privacy.dp_clip <= 0.0:
        raise ValueError(
            "privacy.dp_noise_multiplier > 0 requires privacy.dp_clip > 0 "
            "(the noise stddev is sigma * clip; an unclipped release has "
            "unbounded sensitivity and no (eps, delta) guarantee)")
    if fed.robust_agg not in ("mean", "median", "trimmed_mean",
                              "norm_clip"):
        raise ValueError(f"unknown robust_agg {fed.robust_agg!r}")
    if not 0.0 <= fed.trim_frac < 0.5:
        raise ValueError("trim_frac must be in [0, 0.5): trimming half "
                         "the cohort from each side leaves nothing")
    if not 0.0 <= fed.quorum <= 1.0:
        raise ValueError("quorum is a fraction of the round's starters "
                         "and must be in [0, 1]")
    for rate in (fed.faults.dropout_rate, fed.faults.straggler_rate):
        if not 0.0 <= rate <= 1.0:
            raise ValueError("fault rates are probabilities in [0, 1]")
    if checkpoint_every > 0 and not checkpoint_dir:
        raise ValueError("checkpoint_every > 0 requires checkpoint_dir")
    client_lora_ranks(fed, len(clients))   # validate early
    model = build_model(cfg)
    key = jax.random.PRNGKey(fed.seed)
    base = model.init(key)
    targets = fed.lora_targets or lora_lib.default_targets(cfg)

    # Resolve ModelConfig.kernel_policy for every trace in the run: both
    # execution backends and all three frameworks train through the fused
    # Pallas fwd+bwd kernels when the policy selects them.
    from repro.kernels import ops as kernel_ops
    with kernel_ops.policy_scope(cfg.kernel_policy):
        return run_program(model, base, cfg, fed, targets, public,
                           clients, test, task, batch_size,
                           eval_batch, verbose, backend=backend,
                           mesh=mesh, checkpoint_every=checkpoint_every,
                           checkpoint_dir=checkpoint_dir,
                           resume_from=resume_from)
