"""Single source of truth for the round engine's per-(round, client,
step) key trees.

Three seeded streams feed a federated run, and every engine combination
(framework x backend x aggregation) must draw from the *same* streams so
parity is by construction rather than by re-derivation:

- **Dropout keys** (``local_rng`` / ``grid_keys``): the per-(client,
  round) root each local job splits its per-step dropout keys from.
  Both execution backends use the same root, so sequential/SPMD agree
  bit-exactly at ``lora_dropout == 0`` and draw equally valid masks
  otherwise.
- **Privacy noise keys** (privacy/dp.noise_key): a domain-separated
  ``fold_in`` chain over (seed, round, client[, step]) built on
  ``fold_chain`` below — never the dropout stream.
- **Batching seeds** are plain ints handed to data/loader.epoch_batches
  (per-framework constants in core/round_program.py).

tests/test_rng.py pins all of these against the literal formulas the
pre-pipeline engines used, so refactors cannot silently shift a stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fold_chain(key, *vals):
    """``fold_in`` chained over ``vals`` — the backend-free derivation
    primitive every key tree in the engine reduces to."""
    for v in vals:
        key = jax.random.fold_in(key, v)
    return key


def host_fold_rng(seed: int, *vals) -> np.random.Generator:
    """Host-side counterpart of ``fold_chain``: a numpy ``Generator``
    seeded by folding ``vals`` into ``PRNGKey(seed)`` and reading the
    resulting key data out as the seed sequence.

    The derivation is order-sensitive and collision-resistant the same
    way the device streams are, so host-side per-entity randomness (a
    virtual client's data shard, for instance) is bit-stable no matter
    which order — or how many times — entities are materialized."""
    key = fold_chain(jax.random.PRNGKey(int(seed)), *(int(v) for v in vals))
    try:
        data = jax.random.key_data(key)
    except Exception:        # legacy uint32 key arrays on older jax
        data = key
    words = np.asarray(data, dtype=np.uint32).ravel().tolist()
    return np.random.default_rng(words)


def local_rng(fed, rnd: int, ci: int):
    """Per-(client, round) dropout-key root for one local job."""
    return jax.random.PRNGKey(fed.seed * 1013 + rnd * 131 + ci)


def grid_keys(fed, rnd: int, cis, n_steps: int):
    """(|cis|, n_steps) dropout-key grid for a stacked SPMD program:
    row k is ``jax.random.split(local_rng(fed, rnd, cis[k]), n_steps)``
    — the exact per-step keys a stacked client consumes."""
    return jnp.stack([jax.random.split(local_rng(fed, rnd, ci), n_steps)
                      for ci in cis])
