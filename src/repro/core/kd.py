"""KD-FedLLMs — logit-based knowledge sharing (paper SSII.B):

    b1 client: local fine-tuning on private data
    b2 client: logits on the PUBLIC dataset with the fine-tuned model
    b3 clients -> server: logits (optionally top-k / int8 compressed)
    b4 server: knowledge processing (weighted/filtered aggregation)
    b5 server: distillation -> global model update
    b6 server: global logits on the public dataset
    b7 server -> clients: global logits
    b8 client: local KD against the global knowledge

No parameters cross the network — communication scales with
|public dataset| x logit dim (paper SSIII.B), which is why this framework
wins for classification and loses for generative tasks.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import compression, metrics
from repro.data.loader import epoch_batches


def client_logits(fns, base, lt, public: Dict, batch_size: int = 64):
    """b2: knowledge representations on the public dataset, row i holding
    the logits of public sample i.  Batches arrive permuted (seed-0
    shuffle), so the concatenation is scattered back to original row
    order — distill() indexes teachers by original row id.  Stays on
    device end-to-end (the scatter is a jnp gather-free ``.at[].set``),
    so the b3 compression that follows never syncs through the host."""
    outs = []
    for batch in epoch_batches(public, batch_size, seed=0,
                               drop_remainder=False):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        outs.append(fns["logits_fn"](base, lt, jb))
    stacked = jnp.concatenate(outs, axis=0)
    perm = jnp.asarray(_epoch_perm(len(public["tokens"]), 0))
    return jnp.zeros_like(stacked).at[perm].set(stacked)


def compress_for_wire(logits, fed: FedConfig):
    """b3 compression (SSIV.B.2 features).  Returns (logits', wire_bytes).

    Pure device path: with both ``logit_topk`` and ``logit_quant_bits``
    set, selection + quantization run as ONE fused Pallas kernel
    (kernels/quantize.topk_quantize_rows); no ``np.asarray`` anywhere, so
    the KD round loop performs zero host transfers for logit upload."""
    x = jnp.asarray(logits)
    if fed.logit_topk and fed.logit_topk < x.shape[-1]:
        if fed.logit_quant_bits:
            comp, wire = compression.topk_quantize(x, fed.logit_topk,
                                                   fed.logit_quant_bits)
            return compression.topk_dequantize(comp), wire
        comp, wire = compression.topk_compress(x, fed.logit_topk)
        return compression.topk_decompress(comp), wire
    if fed.logit_quant_bits:
        return compression.quant_roundtrip(x, fed.logit_quant_bits)
    return x, x.size * 4


def logit_wire_bytes(shape, fed: FedConfig) -> int:
    """Arithmetic twin of ``compress_for_wire``'s byte accounting for a
    logit tensor of ``shape`` — use when only the ledger entry is needed
    (e.g. the b7 download of already-produced global logits) so no
    compression pipeline runs just to be discarded."""
    n, d = math.prod(shape[:-1]), shape[-1]
    topk = fed.logit_topk if (fed.logit_topk and fed.logit_topk < d) else 0
    return metrics.logit_bytes(n, d, topk, fed.logit_quant_bits)


def aggregate_knowledge(client_logits_list: List,
                        weights: Optional[List[float]] = None,
                        entropy_filter_frac: float = 0.0) -> jax.Array:
    """b4: refined global knowledge.  Weighted mean of client logits, with
    optional entropy-based filtering (SSIV.B.3): samples whose mean
    predictive entropy is in the highest ``frac`` quantile are replaced by
    the lowest-entropy client's logits (most-confident knowledge wins).
    jnp end-to-end, so the b3 -> b4 chain stays on the accelerator."""
    if weights is None:
        weights = [1.0] * len(client_logits_list)
    w = jnp.asarray(weights, jnp.float32)
    w = _normalized_w(w)
    stack = jnp.stack([jnp.asarray(x) for x in client_logits_list])
    agg = jnp.einsum("c,cnd->nd", w,
                     stack.astype(jnp.float32)).astype(jnp.float32)
    if entropy_filter_frac > 0.0:
        ent = _entropy_jnp(stack)                          # (C, N)
        mean_ent = ent.mean(axis=0)
        thresh = jnp.quantile(mean_ent, 1.0 - entropy_filter_frac)
        noisy = mean_ent >= thresh
        best_client = ent.argmin(axis=0)                   # (N,)
        chosen = stack[best_client, jnp.arange(stack.shape[1])]
        agg = jnp.where(noisy[:, None], chosen, agg)
    return agg


def aggregate_knowledge_batched(stacked, weights) -> jax.Array:
    """b4 as a client-axis reduction for the SPMD backend: weighted mean
    over axis 0 of a (C, N, D) logit stack in fp32 — lowers to one
    all-reduce when the client axis is sharded over pods."""
    w = jnp.asarray(weights, jnp.float32)
    w = _normalized_w(w)
    return jnp.einsum("c,cnd->nd", w, jnp.asarray(stacked, jnp.float32))


def _normalized_w(w: jax.Array) -> jax.Array:
    """Normalize knowledge weights; a zero-mass cohort (every client
    dropped/quarantined) degrades to a uniform mean instead of NaN.
    Bit-transparent for positive totals."""
    s = w.sum()
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0),
                     1.0 / w.shape[0])


def _entropy_jnp(logits) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(jnp.exp(logp) * logp).sum(axis=-1)


def distill(fns, base, lt, opt_state, public: Dict, teacher: np.ndarray,
            epochs: int, batch_size: int = 64, seed: int = 0):
    """b5/b8: update LoRA params by distilling ``teacher`` logits."""
    rng = jax.random.PRNGKey(seed)
    loss = 0.0
    n = 0
    for ep in range(epochs):
        start = 0
        for batch in epoch_batches(public, batch_size, seed=ep,
                                   drop_remainder=False):
            # teacher rows must follow the same permutation
            sel = _epoch_perm(len(public["tokens"]), ep)[
                start:start + len(batch["tokens"])]
            start += len(batch["tokens"])
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t = jnp.asarray(teacher[sel])
            rng, sub = jax.random.split(rng)
            lt, opt_state, l = fns["kd_step"](base, lt, opt_state, jb, t,
                                              sub)
            loss += float(l) * len(batch["tokens"])
            n += len(batch["tokens"])
    return lt, opt_state, loss / max(n, 1)


def _epoch_perm(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).permutation(n)


# --------------------------------------------------------------------------- #
# Public-dataset alignment (SSIV.B.1 — beyond-paper feature)
# --------------------------------------------------------------------------- #
def align_public_dataset(public: Dict, client_label_hists: List[np.ndarray],
                         target_size: int, seed: int = 0) -> Dict:
    """Importance-resample the public dataset toward the clients' average
    label distribution, using only the lightweight histograms clients
    share (no raw data crosses the network)."""
    rng = np.random.default_rng(seed)
    target = np.mean(np.stack(client_label_hists), axis=0)
    labels = public["labels"]
    pub_hist = np.bincount(labels, minlength=len(target)).astype(np.float64)
    pub_hist /= max(pub_hist.sum(), 1.0)
    w = target[labels] / np.maximum(pub_hist[labels], 1e-9)
    w /= w.sum()
    sel = rng.choice(len(labels), size=target_size, replace=True, p=w)
    return {k: v[sel] for k, v in public.items()}
