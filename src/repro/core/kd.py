"""KD-FedLLMs — logit-based knowledge sharing (paper SSII.B):

    b1 client: local fine-tuning on private data
    b2 client: logits on the PUBLIC dataset with the fine-tuned model
    b3 clients -> server: logits (optionally top-k / int8 compressed)
    b4 server: knowledge processing (weighted/filtered aggregation)
    b5 server: distillation -> global model update
    b6 server: global logits on the public dataset
    b7 server -> clients: global logits
    b8 client: local KD against the global knowledge

No parameters cross the network — communication scales with
|public dataset| x logit dim (paper SSIII.B), which is why this framework
wins for classification and loses for generative tasks.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import compression, metrics
from repro.data.loader import epoch_batches


def client_logits(fns, base, lt, public: Dict, batch_size: int = 64):
    """b2: knowledge representations on the public dataset, row i holding
    the logits of public sample i.  Batches arrive permuted (seed-0
    shuffle), so the concatenation is scattered back to original row
    order — distill() indexes teachers by original row id."""
    outs = []
    for batch in epoch_batches(public, batch_size, seed=0,
                               drop_remainder=False):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        outs.append(np.asarray(fns["logits_fn"](base, lt, jb)))
    stacked = np.concatenate(outs, axis=0)
    out = np.empty_like(stacked)
    out[_epoch_perm(len(public["tokens"]), 0)] = stacked
    return out


def compress_for_wire(logits: np.ndarray, fed: FedConfig):
    """b3 compression (SSIV.B.2 features).  Returns (logits', wire_bytes)."""
    x = jnp.asarray(logits)
    if fed.logit_topk and fed.logit_topk < logits.shape[-1]:
        comp, wire = compression.topk_compress(x, fed.logit_topk)
        return np.asarray(compression.topk_decompress(comp)), wire
    if fed.logit_quant_bits:
        deq, wire = compression.quant_roundtrip(x, fed.logit_quant_bits)
        return np.asarray(deq), wire
    return logits, logits.size * 4


def aggregate_knowledge(client_logits_list: List[np.ndarray],
                        weights: Optional[List[float]] = None,
                        entropy_filter_frac: float = 0.0) -> np.ndarray:
    """b4: refined global knowledge.  Weighted mean of client logits, with
    optional entropy-based filtering (SSIV.B.3): samples whose mean
    predictive entropy is in the highest ``frac`` quantile are replaced by
    the lowest-entropy client's logits (most-confident knowledge wins)."""
    if weights is None:
        weights = [1.0] * len(client_logits_list)
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    stack = np.stack(client_logits_list)                   # (C, N, D)
    agg = np.einsum("c,cnd->nd", w, stack).astype(np.float32)
    if entropy_filter_frac > 0.0:
        ent = _entropy(stack)                              # (C, N)
        mean_ent = ent.mean(axis=0)
        thresh = np.quantile(mean_ent, 1.0 - entropy_filter_frac)
        noisy = mean_ent >= thresh
        best_client = ent.argmin(axis=0)                   # (N,)
        chosen = stack[best_client, np.arange(stack.shape[1])]
        agg[noisy] = chosen[noisy]
    return agg


def aggregate_knowledge_batched(stacked, weights) -> jax.Array:
    """b4 as a client-axis reduction for the SPMD backend: weighted mean
    over axis 0 of a (C, N, D) logit stack in fp32 — lowers to one
    all-reduce when the client axis is sharded over pods."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / w.sum()
    return jnp.einsum("c,cnd->nd", w, jnp.asarray(stacked, jnp.float32))


def _entropy(logits: np.ndarray) -> np.ndarray:
    x = logits - logits.max(axis=-1, keepdims=True)
    p = np.exp(x)
    p /= p.sum(axis=-1, keepdims=True)
    return -(p * np.log(np.maximum(p, 1e-12))).sum(axis=-1)


def distill(fns, base, lt, opt_state, public: Dict, teacher: np.ndarray,
            epochs: int, batch_size: int = 64, seed: int = 0):
    """b5/b8: update LoRA params by distilling ``teacher`` logits."""
    rng = jax.random.PRNGKey(seed)
    loss = 0.0
    n = 0
    for ep in range(epochs):
        start = 0
        for batch in epoch_batches(public, batch_size, seed=ep,
                                   drop_remainder=False):
            # teacher rows must follow the same permutation
            sel = _epoch_perm(len(public["tokens"]), ep)[
                start:start + len(batch["tokens"])]
            start += len(batch["tokens"])
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t = jnp.asarray(teacher[sel])
            rng, sub = jax.random.split(rng)
            lt, opt_state, l = fns["kd_step"](base, lt, opt_state, jb, t,
                                              sub)
            loss += float(l) * len(batch["tokens"])
            n += len(batch["tokens"])
    return lt, opt_state, loss / max(n, 1)


def _epoch_perm(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).permutation(n)


# --------------------------------------------------------------------------- #
# Public-dataset alignment (SSIV.B.1 — beyond-paper feature)
# --------------------------------------------------------------------------- #
def align_public_dataset(public: Dict, client_label_hists: List[np.ndarray],
                         target_size: int, seed: int = 0) -> Dict:
    """Importance-resample the public dataset toward the clients' average
    label distribution, using only the lightweight histograms clients
    share (no raw data crosses the network)."""
    rng = np.random.default_rng(seed)
    target = np.mean(np.stack(client_label_hists), axis=0)
    labels = public["labels"]
    pub_hist = np.bincount(labels, minlength=len(target)).astype(np.float64)
    pub_hist /= max(pub_hist.sum(), 1.0)
    w = target[labels] / np.maximum(pub_hist[labels], 1e-9)
    w /= w.sum()
    sel = rng.choice(len(labels), size=target_size, replace=True, p=w)
    return {k: v[sel] for k, v in public.items()}
