"""SPMD execution backend (``FedConfig(backend="spmd")``) — a thin
adapter over the unified pipeline.

Since the RoundProgram refactor the per-framework host drivers that
used to live here are gone: core/round_program.py's ``SpmdExecutor``
runs every framework's ready-set as stacked per-rank bucketed programs
(contiguous equal-rank segments for Split, preserving the paper's
server-half visit order) built from core/fed_spmd.py, under both sync
and async aggregation, with privacy and heterogeneous ranks applied as
middleware — identical ledger bytes to the sequential backend by
construction (tests/test_backend_parity.py).

Given a mesh (``run_federated(..., mesh=...)``), the executor places
the stacked client axis on the mesh's client axes with explicit
NamedShardings (launch/sharding.py), so the client dimension of a real
run shards over the pod/data axes — not just in the dry-run.

Parity contract: per-round ledger bytes and client FLOPs match the
sequential backend exactly; accuracy/loss match within fp32 tolerance
(vmapped/batched reductions reorder float ops).  With ``lora_dropout >
0`` the backends draw different (equally valid) dropout masks from the
same per-(client, round) roots (core/rng.py) — bit-level parity is only
defined at dropout 0.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.round_program import run_program


def run_spmd(model, base, cfg, fed, targets, public: Dict,
             clients_data: List[Dict], test: Dict, task: str,
             batch_size: int, eval_batch: int, verbose: bool, mesh=None):
    return run_program(model, base, cfg, fed, targets, public,
                       clients_data, test, task, batch_size, eval_batch,
                       verbose, backend="spmd", mesh=mesh)
