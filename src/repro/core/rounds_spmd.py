"""Host drivers for the SPMD execution backend
(``FedConfig(backend="spmd")`` — selected by core/rounds.run_federated).

Each framework's parameter-server round runs as one jitted program over
stacked per-client state (core/fed_spmd.py).  This module feeds those
programs the stacked batch tensors, keeps the paper's communication
ledger identical to the sequential backend (every wire size is derived
from shapes, so byte totals agree exactly), and evaluates with the same
jitted eval step.

Parity contract (tests/test_backend_parity.py): per-round ledger bytes
and client FLOPs match the sequential backend exactly; accuracy/loss
match within fp32 tolerance (vmapped/batched reductions reorder float
ops).  With ``lora_dropout > 0`` the backends draw different dropout
masks — the sequential loop threads one RNG through clients in visit
order, the SPMD programs use per-(client, step) keys — so bit-level
parity is only defined at dropout 0.

Heterogeneous LoRA ranks (``FedConfig.client_ranks``) run as per-rank
*buckets*: clients sharing a rank stack on one leading axis and run one
jitted program per bucket, then the buckets harmonize through the same
``core/heterogeneous.aggregate_hetero`` (zeropad | svd) the sequential
backend uses.  Split-FedLLM buckets only contiguous equal-rank runs
(``fed_spmd.rank_segments``) — the shared server half is trained
client-after-client, and reordering clients would change the paper's
optimization trajectory.  Wire bytes stay per-simulated-client and
rank-exact (``CommLedger.record_bucket``), so Fig. 4 extends to the
heterogeneous setting unchanged.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed_spmd
from repro.core import kd as kd_mod
from repro.core import metrics as M
from repro.core import split as split_mod
from repro.core.fedavg import evaluate, make_fns
from repro.core.heterogeneous import harmonize_buckets
from repro.core.rounds import (FedResult, client_lora_ranks,
                               make_accountant, round_epsilon)
from repro.data.loader import epoch_batches
from repro.peft import lora as lora_lib
from repro.privacy import dp as dp_mod
from repro.privacy.secure_agg import SecureAggSession


def run_spmd(model, base, cfg, fed, targets, public: Dict,
             clients_data: List[Dict], test: Dict, task: str,
             batch_size: int, eval_batch: int, verbose: bool):
    runner = {"fedllm": _run_fedllm_spmd, "kd": _run_kd_spmd,
              "split": _run_split_spmd}[fed.framework]
    return runner(model, base, cfg, fed, targets, public, clients_data,
                  test, task, batch_size, eval_batch, verbose)


def _client_weights(clients_data):
    w = [len(d["tokens"]) for d in clients_data]
    return w, jnp.asarray(np.asarray(w, np.float32))


# --------------------------------------------------------------------------- #
# 1) FedLLMs
# --------------------------------------------------------------------------- #
def _run_fedllm_spmd(model, base, cfg, fed, targets, public, clients_data,
                     test, task, batch_size, eval_batch, verbose):
    ranks = client_lora_ranks(fed, len(clients_data))
    if len(set(ranks)) > 1:
        return _run_fedllm_spmd_hetero(model, base, cfg, fed, targets,
                                       clients_data, test, task, batch_size,
                                       eval_batch, verbose, ranks)
    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 1)
    n_clients = len(clients_data)
    global_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                   fed.lora_alpha)
    round_step = jax.jit(fed_spmd.make_spmd_round(model, fed, task))
    priv, acct = fed.privacy, make_accountant(fed)
    noised = priv.noise_std > 0.0
    secagg = SecureAggSession(fed)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    _, wj = _client_weights(clients_data)
    lt_bytes = M.tree_bytes(global_lt)
    n_lora = lora_lib.n_params(global_lt)

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        seeds = [fed.seed * 997 + rnd + ep for ep in range(fed.local_epochs)]
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, seeds)
        # a1: distribute the (identical) global params to every slot
        ledger.record_batch(rnd, "lora_params", M.DOWN,
                            [lt_bytes] * n_clients)
        stacked_lt = fed_spmd.stack_for_clients(global_lt, n_clients)
        stacked_opt = fed_spmd.stack_for_clients(fns["opt_init"](global_lt),
                                                 n_clients)
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        # a2-a4 as one program: vmapped local scans (+ in-program DP
        # payload noise from the shared per-client fold_in keys) +
        # client-axis FedAvg; the pre-aggregation uploads come back for
        # the secure-agg masking overlay
        extra = (jnp.stack([dp_mod.noise_key(fed, rnd, ci)
                            for ci in range(n_clients)]),) if noised else ()
        redist, _, _, uploaded = round_step(
            base, stacked_lt, stacked_opt, batches, keys,
            jnp.asarray(valid), wj, *extra)
        global_lt = jax.tree.map(lambda x: x[0], redist)
        # a3: upload — same shapes as the download
        ledger.record_batch(rnd, "lora_params", M.UP, [lt_bytes] * n_clients)
        if priv.dp_enabled:
            ledger.record_batch(rnd, "dp_meta", M.UP,
                                [M.DP_META_BYTES] * n_clients)
        if secagg.enabled:
            for ci, t in enumerate(fed_spmd.unstack_tree(uploaded)):
                secagg.collect(rnd, ci, t)
            secagg.deliver(ledger, rnd,
                           [(rnd, ci) for ci in range(n_clients)])
        for ci in range(n_clients):
            cost[ci].add_train(cfg, n_tok[ci], n_lora)
        acc, loss = evaluate(fns, base, global_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, rnd + 1)))
        if verbose:
            print(f"[fedllm/spmd] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, global_lt, [c.flops for c in cost])


def _run_fedllm_spmd_hetero(model, base, cfg, fed, targets, clients_data,
                            test, task, batch_size, eval_batch, verbose,
                            ranks):
    """Per-rank bucketed FedLLM round: one jitted stacked program per
    bucket (vmapped local scans, no in-program FedAvg), then zeropad/svd
    harmonization across buckets — the sequential backend's exact
    aggregation code path, fed in client visit order."""
    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 1)
    n_clients = len(clients_data)
    global_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                   fed.lora_alpha)
    bucket_update = fed_spmd.make_bucket_update(model, fed, task)
    buckets = fed_spmd.rank_buckets(ranks)
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, _ = _client_weights(clients_data)

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        seeds = [fed.seed * 997 + rnd + ep for ep in range(fed.local_epochs)]
        bucket_trees, bucket_clients = [], []
        for rank, cis in buckets:
            # a1: distribute (truncated) global params to the bucket
            lt0 = lora_lib.maybe_truncate_rank(global_lt, rank,
                                               fed.lora_rank)
            lt_bytes = M.tree_bytes(lt0)
            n_lora = lora_lib.n_params(lt0)
            ledger.record_bucket(rnd, cis, "lora_params", M.DOWN, lt_bytes)
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [clients_data[ci] for ci in cis], batch_size, seeds)
            stacked_lt = fed_spmd.stack_for_clients(lt0, len(cis))
            stacked_opt = fed_spmd.stack_for_clients(fns["opt_init"](lt0),
                                                     len(cis))
            key, sub = jax.random.split(key)
            keys = fed_spmd.split_keys(sub, len(cis), valid.shape[1])
            # a2: one stacked program per bucket
            new_lt, _, _ = bucket_update(base, stacked_lt, stacked_opt,
                                         batches, keys, jnp.asarray(valid))
            # a3: upload — rank-exact per-bucket wire bytes; DP payload
            # noise per client (host side — the bucket programs return
            # pre-aggregation trees anyway), then secure-agg masking
            trees = fed_spmd.unstack_tree(new_lt)
            trees = [dp_mod.privatize_tree(
                t, dp_mod.noise_key(fed, rnd, ci), priv.noise_std)
                for ci, t in zip(cis, trees)]
            ledger.record_bucket(rnd, cis, "lora_params", M.UP, lt_bytes)
            if priv.dp_enabled:
                ledger.record_bucket(rnd, cis, "dp_meta", M.UP,
                                     M.DP_META_BYTES)
            for k, ci in enumerate(cis):
                secagg.collect(rnd, ci, trees[k])
                cost[ci].add_train(cfg, n_tok[k], n_lora)
            bucket_trees.append(trees)
            bucket_clients.append(list(cis))
        # a4: cross-bucket harmonization (zeropad | svd)
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        global_lt = harmonize_buckets(bucket_trees, bucket_clients, ranks,
                                      fed.lora_alpha, fed.lora_rank,
                                      weights, fed.hetero_agg)
        acc, loss = evaluate(fns, base, global_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, rnd + 1)))
        if verbose:
            print(f"[fedllm/spmd-hetero] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, global_lt, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 2) KD-FedLLMs
# --------------------------------------------------------------------------- #
def _batched_public_logits(kfns, base, stacked_lt, public, batch_size):
    """b2/b6 for every client at once — same batch order and original-
    row-order scatter as kd.client_logits, giving (C, N, D) with row i
    holding public sample i's logits.  Device arrays end-to-end: the b3
    compression that follows never syncs through the host."""
    outs = []
    for batch in epoch_batches(public, batch_size, seed=0,
                               drop_remainder=False):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        outs.append(kfns["batched_logits"](base, stacked_lt, jb))
    stacked = jnp.concatenate(outs, axis=1)
    perm = jnp.asarray(kd_mod._epoch_perm(len(public["tokens"]), 0))
    return jnp.zeros_like(stacked).at[:, perm].set(stacked)


def _batched_distill(kfns, base, stacked_lt, stacked_opt, public, teacher,
                     fed, batch_size, rnd, client_ids):
    """b8 for every client in a (bucket-)stack at once.  Clients distill
    against the SAME global knowledge over the SAME public batch order
    (kd.distill), so the per-batch step vmaps cleanly over the client
    axis.  Per-client RNG streams match the sequential backend's
    PRNGKey(seed + 31r + ci) — ``client_ids`` carries the *global*
    client indices of the stack's rows."""
    rngs = jnp.stack([jax.random.PRNGKey(fed.seed + 31 * rnd + ci)
                      for ci in client_ids])
    n = len(public["tokens"])
    for ep in range(fed.kd_epochs):
        perm = kd_mod._epoch_perm(n, ep)
        start = 0
        for batch in epoch_batches(public, batch_size, seed=ep,
                                   drop_remainder=False):
            sel = perm[start:start + len(batch["tokens"])]
            start += len(batch["tokens"])
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t = jnp.asarray(teacher[sel])
            rngs, subs = fed_spmd.split_each(rngs)
            stacked_lt, stacked_opt, _ = kfns["batched_kd_step"](
                base, stacked_lt, stacked_opt, jb, t, subs)
    return stacked_lt, stacked_opt


def _run_kd_spmd(model, base, cfg, fed, targets, public, clients_data,
                 test, task, batch_size, eval_batch, verbose):
    """KD round over per-rank buckets (homogeneous ranks = one bucket,
    which is exactly the old single-stack program).  Params never cross
    the wire in KD, so heterogeneity costs nothing at the protocol level
    — each bucket's stack just trains and produces knowledge at its own
    rank, and the (C, N, D) logit reduction is rank-agnostic."""
    fns = make_fns(model, fed, task)
    kfns = fed_spmd.make_kd_spmd_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 2)
    n_clients = len(clients_data)
    ranks = client_lora_ranks(fed, n_clients)
    buckets = fed_spmd.rank_buckets(ranks)
    priv, acct = fed.privacy, make_accountant(fed)
    secagg = SecureAggSession(fed)

    # per-bucket stacked client state (same fold_in(key, ci) init stream
    # as the sequential backend, so hetero init is bit-identical)
    b_lts, b_opts, b_nlora = [], [], []
    for rank, cis in buckets:
        lts = [lora_lib.init_lora(jax.random.fold_in(key, ci), base,
                                  targets, rank, fed.lora_alpha)
               for ci in cis]
        b_lts.append(fed_spmd.stack_trees(lts))
        b_opts.append(fed_spmd.stack_for_clients(fns["opt_init"](lts[0]),
                                                 len(cis)))
        b_nlora.append(lora_lib.n_params(lts[0]))
    server_lt = lora_lib.init_lora(jax.random.fold_in(key, 999), base,
                                   targets, fed.lora_rank, fed.lora_alpha)
    server_opt = fns["opt_init"](server_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, _ = _client_weights(clients_data)
    pub_tok = public["tokens"].size

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        seeds = [fed.seed * 991 + rnd + ep for ep in range(fed.local_epochs)]
        uploaded = [None] * n_clients
        for bi, (rank, cis) in enumerate(buckets):
            # b1: vmapped local fine-tuning (one program per bucket)
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [clients_data[ci] for ci in cis], batch_size, seeds)
            key, sub = jax.random.split(key)
            keys = fed_spmd.split_keys(sub, len(cis), valid.shape[1])
            b_lts[bi], b_opts[bi], _ = kfns["client_update"](
                base, b_lts[bi], b_opts[bi], batches, keys,
                jnp.asarray(valid))
            # b2: batched logit production on the public set -> (|b|, N, D)
            logits_cnd = _batched_public_logits(kfns, base, b_lts[bi],
                                                public, eval_batch)
            # b3: per-simulated-client privatization (row-clipped noisy
            # logits — same fold_in keys as the sequential backend) +
            # compression + upload accounting
            for k, ci in enumerate(cis):
                lg = dp_mod.privatize_logits(
                    logits_cnd[k], dp_mod.noise_key(fed, rnd, ci), fed)
                lg, wire = kd_mod.compress_for_wire(lg, fed)
                ledger.record(rnd, ci, "logits", M.UP, wire)
                if priv.dp_enabled:
                    ledger.record(rnd, ci, "dp_meta", M.UP,
                                  M.DP_META_BYTES)
                secagg.collect(rnd, ci, lg)
                uploaded[ci] = lg
                cost[ci].add_train(cfg, n_tok[k], b_nlora[bi])
                cost[ci].add_fwd(cfg, pub_tok)
        # b4: knowledge processing as a client-axis reduction (on device)
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        teacher = kd_mod.aggregate_knowledge_batched(
            jnp.stack(uploaded), weights)
        # b5: server-side distillation into the global model
        server_lt, server_opt, _ = kd_mod.distill(
            fns, base, server_lt, server_opt, public, teacher,
            fed.kd_epochs, eval_batch, seed=fed.seed + rnd)
        # b6/b7: global logits back to every client (arithmetic wire size)
        glob = kd_mod.client_logits(fns, base, server_lt, public, eval_batch)
        glob_wire = kd_mod.logit_wire_bytes(glob.shape, fed)
        ledger.record_batch(rnd, "logits", M.DOWN, [glob_wire] * n_clients)
        # b8: vmapped client-side distillation, one program per bucket
        for bi, (rank, cis) in enumerate(buckets):
            b_lts[bi], b_opts[bi] = _batched_distill(
                kfns, base, b_lts[bi], b_opts[bi], public, glob, fed,
                eval_batch, rnd, cis)
            for ci in cis:
                cost[ci].add_train(cfg, pub_tok * fed.kd_epochs,
                                   b_nlora[bi])
        acc, loss = evaluate(fns, base, server_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, rnd + 1)))
        if verbose:
            print(f"[kd/spmd] round {rnd}: acc={acc:.4f} loss={loss:.4f}")
    return FedResult(history, ledger, server_lt, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 3) Split-FedLLMs
# --------------------------------------------------------------------------- #
def _run_split_spmd(model, base, cfg, fed, targets, public, clients_data,
                    test, task, batch_size, eval_batch, verbose):
    ranks = client_lora_ranks(fed, len(clients_data))
    if len(set(ranks)) > 1:
        return _run_split_spmd_hetero(model, base, cfg, fed, targets,
                                      clients_data, test, task, batch_size,
                                      eval_batch, verbose, ranks)
    fns = make_fns(model, fed, task)           # for eval on the full model
    sfns = split_mod.make_split_fns(model, fed, task)
    round_step = jax.jit(fed_spmd.make_split_spmd_round(model, fed, task,
                                                        sfns=sfns))
    key = jax.random.PRNGKey(fed.seed + 3)
    n_clients = len(clients_data)
    L = sfns["n_client_groups"]
    frac_client = L / max(sfns["n_groups"], 1)
    priv, acct = fed.privacy, make_accountant(fed)
    noised = priv.noise_std > 0.0
    secagg = SecureAggSession(fed)
    releases = 0

    full_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                 fed.lora_alpha)
    c_global, s_lt = split_mod.split_lora(full_lt, L)
    base_c, base_s = split_mod.split_base(base, L, cfg.is_encoder_decoder)
    s_opt = sfns["opt_init"](s_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, wj = _client_weights(clients_data)
    c_bytes = M.tree_bytes(c_global)
    n_c_lora = lora_lib.n_params(c_global)
    joined = full_lt

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, [fed.seed * 983 + rnd])
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        # wire bytes are shape-derived — identical per (client, batch)
        up, down = sfns["wire_bytes_per_batch"](batches["tokens"].shape[-2:])
        lbl = batches["labels"][0, 0].size * 4 if "labels" in batches else 0
        for ci in range(n_clients):
            ledger.record(rnd, ci, "lora_params", M.DOWN, c_bytes)   # cc3
            for _ in range(int(valid[ci].sum())):
                ledger.record(rnd, ci, "activations", M.UP, up + lbl)  # c2
                ledger.record(rnd, ci, "act_grads", M.DOWN, down)      # c4
                if priv.dp_enabled:
                    ledger.record(rnd, ci, "dp_meta", M.UP,
                                  M.DP_META_BYTES)
            cost[ci].add_train(cfg, n_tok[ci], n_c_lora,
                               frac_layers=frac_client)
            ledger.record(rnd, ci, "lora_params", M.UP, c_bytes)     # cc1
        extra = (dp_mod.noise_key_grid(fed, rnd, range(n_clients),
                                       valid.shape[1]),) if noised else ()
        c_global, s_lt, s_opt, _, stacked_c = round_step(
            base_c, base_s, c_global, s_lt, s_opt, batches, keys,
            jnp.asarray(valid), wj, *extra)
        if secagg.enabled:
            for ci, t in enumerate(fed_spmd.unstack_tree(stacked_c)):
                secagg.collect(rnd, ci, t)
            secagg.deliver(ledger, rnd,
                           [(rnd, ci) for ci in range(n_clients)])
        releases += int(valid.sum(axis=1).max())
        joined = split_mod.join_lora(c_global, s_lt)
        acc, loss = evaluate(fns, base, joined, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, releases)))
        if verbose:
            print(f"[split/spmd] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, joined, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# Async executors (core/async_agg.py drives; this backend runs each
# round's ready-set as per-rank bucketed stacked programs)
# --------------------------------------------------------------------------- #
def _grid_keys(fed, rnd, cis, n_steps):
    """(|bucket|, S) dropout-key grid from the shared per-(client, round)
    async RNG stream, so sequential/SPMD async agree at dropout 0 and
    draw equally valid masks otherwise."""
    from repro.core.async_agg import _local_rng
    return jnp.stack([jax.random.split(_local_rng(fed, rnd, ci), n_steps)
                      for ci in cis])


def spmd_fedllm_exec(model, base, cfg, fed, targets, clients_data, public,
                     task, batch_size, eval_batch, ranks):
    fns = make_fns(model, fed, task)
    bucket_update = fed_spmd.make_bucket_update(model, fed, task)

    def train(jobs, rnd):
        by_ci = dict(jobs)
        seeds = [fed.seed * 997 + rnd + ep for ep in range(fed.local_epochs)]
        results = {}
        for rank, cis in fed_spmd.rank_buckets(ranks, list(by_ci)):
            stacked_lt = fed_spmd.stack_trees([by_ci[ci] for ci in cis])
            stacked_opt = fed_spmd.stack_for_clients(
                fns["opt_init"](by_ci[cis[0]]), len(cis))
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [clients_data[ci] for ci in cis], batch_size, seeds)
            keys = _grid_keys(fed, rnd, cis, valid.shape[1])
            new_lt, _, _ = bucket_update(base, stacked_lt, stacked_opt,
                                         batches, keys, jnp.asarray(valid))
            for k, (ci, t) in enumerate(
                    zip(cis, fed_spmd.unstack_tree(new_lt))):
                results[ci] = (t, n_tok[k])
        return [results[ci] for ci, _ in jobs]

    from types import SimpleNamespace
    return SimpleNamespace(fns=fns, targets=targets, train=train)


def spmd_kd_exec(model, base, cfg, fed, targets, clients_data, public,
                 task, batch_size, eval_batch, ranks):
    from repro.core.async_agg import make_kd_state

    ex = make_kd_state(model, base, fed, targets, ranks, public, task)
    kfns = fed_spmd.make_kd_spmd_fns(model, fed, task)
    lts, opts = ex.lts, ex.opts

    def train_and_logits(cis, rnd):
        seeds = [fed.seed * 991 + rnd + ep for ep in range(fed.local_epochs)]
        results = {}
        for rank, bcis in fed_spmd.rank_buckets(ranks, cis):
            sl = fed_spmd.stack_trees([lts[ci] for ci in bcis])
            so = fed_spmd.stack_trees([opts[ci] for ci in bcis])
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [clients_data[ci] for ci in bcis], batch_size, seeds)
            keys = _grid_keys(fed, rnd, bcis, valid.shape[1])
            sl, so, _ = kfns["client_update"](base, sl, so, batches, keys,
                                              jnp.asarray(valid))
            logits = _batched_public_logits(kfns, base, sl, public,
                                            eval_batch)
            for k, (ci, lt, opt) in enumerate(zip(
                    bcis, fed_spmd.unstack_tree(sl),
                    fed_spmd.unstack_tree(so))):
                lts[ci], opts[ci] = lt, opt
                results[ci] = (logits[k], n_tok[k])
        return [results[ci] for ci in cis]

    def distill(cis, glob, rnd):
        for rank, bcis in fed_spmd.rank_buckets(ranks, cis):
            sl = fed_spmd.stack_trees([lts[ci] for ci in bcis])
            so = fed_spmd.stack_trees([opts[ci] for ci in bcis])
            sl, so = _batched_distill(kfns, base, sl, so, public, glob,
                                      fed, eval_batch, rnd, bcis)
            for ci, lt, opt in zip(bcis, fed_spmd.unstack_tree(sl),
                                   fed_spmd.unstack_tree(so)):
                lts[ci], opts[ci] = lt, opt

    ex.train_and_logits, ex.distill = train_and_logits, distill
    return ex


def spmd_split_exec(model, base, cfg, fed, targets, clients_data, public,
                    task, batch_size, eval_batch, ranks):
    from repro.core.async_agg import make_split_state

    ex = make_split_state(model, base, cfg, fed, targets, clients_data,
                          task, batch_size)
    seg_step = jax.jit(fed_spmd.make_split_spmd_segment(model, fed, task,
                                                        sfns=ex.sfns))
    base_c, base_s = ex.base_c, ex.base_s

    noised = fed.privacy.noise_std > 0.0

    def train(jobs, rnd):
        by_ci = dict(jobs)
        results = {}
        # fuse contiguous equal-rank runs of the ready-set; the server
        # carry threads through segments in client visit order
        for rank, cis in fed_spmd.rank_segments(ranks, list(by_ci)):
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [clients_data[ci] for ci in cis], batch_size,
                [fed.seed * 983 + rnd])
            keys = _grid_keys(fed, rnd, cis, valid.shape[1])
            extra = (dp_mod.noise_key_grid(fed, rnd, cis,
                                           valid.shape[1]),) if noised \
                else ()
            stacked_c, ex.s_lt, ex.s_opt, _ = seg_step(
                base_c, base_s, by_ci[cis[0]], ex.s_lt, ex.s_opt, batches,
                keys, jnp.asarray(valid), *extra)
            shape = tuple(batches["tokens"].shape[-2:])
            for k, (ci, t) in enumerate(
                    zip(cis, fed_spmd.unstack_tree(stacked_c))):
                results[ci] = (t, n_tok[k], int(valid[k].sum()), shape)
        return [results[ci] for ci, _ in jobs]

    ex.train = train
    return ex


def _run_split_spmd_hetero(model, base, cfg, fed, targets, clients_data,
                           test, task, batch_size, eval_batch, verbose,
                           ranks):
    """Heterogeneous Split-FedLLM: contiguous equal-rank client runs
    become stacked *segment* programs; the shared server half's carry is
    threaded segment-after-segment, reproducing the sequential backend's
    exact client visit order.  Only the client-side adapters are
    heterogeneous — the closing FedAvg harmonizes them across segments
    (zeropad | svd) back to the global rank."""
    fns = make_fns(model, fed, task)           # for eval on the full model
    sfns = split_mod.make_split_fns(model, fed, task)
    seg_step = jax.jit(fed_spmd.make_split_spmd_segment(model, fed, task,
                                                        sfns=sfns))
    key = jax.random.PRNGKey(fed.seed + 3)
    n_clients = len(clients_data)
    L = sfns["n_client_groups"]
    frac_client = L / max(sfns["n_groups"], 1)
    segments = fed_spmd.rank_segments(ranks)
    priv, acct = fed.privacy, make_accountant(fed)
    noised = priv.noise_std > 0.0
    secagg = SecureAggSession(fed)
    releases = 0

    full_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                 fed.lora_alpha)
    c_global, s_lt = split_mod.split_lora(full_lt, L)
    base_c, base_s = split_mod.split_base(base, L, cfg.is_encoder_decoder)
    s_opt = sfns["opt_init"](s_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, _ = _client_weights(clients_data)
    joined = full_lt

    for rnd in range(fed.rounds):
        secagg.begin_cohort(ledger, rnd, range(n_clients))
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, [fed.seed * 983 + rnd])
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        up, down = sfns["wire_bytes_per_batch"](batches["tokens"].shape[-2:])
        lbl = batches["labels"][0, 0].size * 4 if "labels" in batches else 0
        seg_trees, seg_clients = [], []
        for rank, cis in segments:
            lo, hi = cis[0], cis[-1] + 1       # contiguous by construction
            c_init = lora_lib.maybe_truncate_rank(c_global, rank,
                                                  fed.lora_rank)
            c_bytes = M.tree_bytes(c_init)
            n_c_lora = lora_lib.n_params(c_init)
            for ci in cis:
                ledger.record(rnd, ci, "lora_params", M.DOWN, c_bytes)  # cc3
                for _ in range(int(valid[ci].sum())):
                    ledger.record(rnd, ci, "activations", M.UP,
                                  up + lbl)                             # c2
                    ledger.record(rnd, ci, "act_grads", M.DOWN, down)   # c4
                    if priv.dp_enabled:
                        ledger.record(rnd, ci, "dp_meta", M.UP,
                                      M.DP_META_BYTES)
                cost[ci].add_train(cfg, n_tok[ci], n_c_lora,
                                   frac_layers=frac_client)
                ledger.record(rnd, ci, "lora_params", M.UP, c_bytes)    # cc1
            extra = (dp_mod.noise_key_grid(fed, rnd, cis,
                                           valid.shape[1]),) if noised \
                else ()
            stacked_c, s_lt, s_opt, _ = seg_step(
                base_c, base_s, c_init, s_lt, s_opt,
                {k: v[lo:hi] for k, v in batches.items()},
                keys[lo:hi], jnp.asarray(valid[lo:hi]), *extra)
            trees = fed_spmd.unstack_tree(stacked_c)
            for ci, t in zip(cis, trees):
                secagg.collect(rnd, ci, t)
            seg_trees.append(trees)
            seg_clients.append(list(cis))
        # cc2: harmonize the client halves across segments
        secagg.deliver(ledger, rnd, [(rnd, ci) for ci in range(n_clients)])
        releases += int(valid.sum(axis=1).max())
        c_global = harmonize_buckets(seg_trees, seg_clients, ranks,
                                     fed.lora_alpha, fed.lora_rank,
                                     weights, fed.hetero_agg)
        joined = split_mod.join_lora(c_global, s_lt)
        acc, loss = evaluate(fns, base, joined, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost])),
            epsilon=round_epsilon(acct, releases)))
        if verbose:
            print(f"[split/spmd-hetero] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, joined, [c.flops for c in cost])
