"""Host drivers for the SPMD execution backend
(``FedConfig(backend="spmd")`` — selected by core/rounds.run_federated).

Each framework's parameter-server round runs as one jitted program over
stacked per-client state (core/fed_spmd.py).  This module feeds those
programs the stacked batch tensors, keeps the paper's communication
ledger identical to the sequential backend (every wire size is derived
from shapes, so byte totals agree exactly), and evaluates with the same
jitted eval step.

Parity contract (tests/test_backend_parity.py): per-round ledger bytes
and client FLOPs match the sequential backend exactly; accuracy/loss
match within fp32 tolerance (vmapped/batched reductions reorder float
ops).  With ``lora_dropout > 0`` the backends draw different dropout
masks — the sequential loop threads one RNG through clients in visit
order, the SPMD programs use per-(client, step) keys — so bit-level
parity is only defined at dropout 0.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed_spmd
from repro.core import kd as kd_mod
from repro.core import metrics as M
from repro.core import split as split_mod
from repro.core.fedavg import evaluate, make_fns
from repro.data.loader import epoch_batches
from repro.peft import lora as lora_lib


def run_spmd(model, base, cfg, fed, targets, public: Dict,
             clients_data: List[Dict], test: Dict, task: str,
             batch_size: int, eval_batch: int, verbose: bool):
    if fed.client_ranks and set(fed.client_ranks) != {fed.lora_rank}:
        raise ValueError(
            "backend='spmd' stacks client LoRA trees on one axis and "
            "needs homogeneous client_ranks equal to lora_rank "
            f"(got client_ranks={fed.client_ranks}, "
            f"lora_rank={fed.lora_rank}); use backend='sequential' for "
            "heterogeneous or truncated ranks")
    runner = {"fedllm": _run_fedllm_spmd, "kd": _run_kd_spmd,
              "split": _run_split_spmd}[fed.framework]
    return runner(model, base, cfg, fed, targets, public, clients_data,
                  test, task, batch_size, eval_batch, verbose)


def _client_weights(clients_data):
    w = [len(d["tokens"]) for d in clients_data]
    return w, jnp.asarray(np.asarray(w, np.float32))


# --------------------------------------------------------------------------- #
# 1) FedLLMs
# --------------------------------------------------------------------------- #
def _run_fedllm_spmd(model, base, cfg, fed, targets, public, clients_data,
                     test, task, batch_size, eval_batch, verbose):
    from repro.core.rounds import FedResult

    fns = make_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 1)
    n_clients = len(clients_data)
    global_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                   fed.lora_alpha)
    round_step = jax.jit(fed_spmd.make_spmd_round(model, fed, task))

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    _, wj = _client_weights(clients_data)
    lt_bytes = M.tree_bytes(global_lt)
    n_lora = lora_lib.n_params(global_lt)

    for rnd in range(fed.rounds):
        seeds = [fed.seed * 997 + rnd + ep for ep in range(fed.local_epochs)]
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, seeds)
        # a1: distribute the (identical) global params to every slot
        ledger.record_batch(rnd, "lora_params", M.DOWN,
                            [lt_bytes] * n_clients)
        stacked_lt = fed_spmd.stack_for_clients(global_lt, n_clients)
        stacked_opt = fed_spmd.stack_for_clients(fns["opt_init"](global_lt),
                                                 n_clients)
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        # a2-a4 as one program: vmapped local scans + client-axis FedAvg
        redist, _, _ = round_step(base, stacked_lt, stacked_opt, batches,
                                  keys, jnp.asarray(valid), wj)
        global_lt = jax.tree.map(lambda x: x[0], redist)
        # a3: upload — same shapes as the download
        ledger.record_batch(rnd, "lora_params", M.UP, [lt_bytes] * n_clients)
        for ci in range(n_clients):
            cost[ci].add_train(cfg, n_tok[ci], n_lora)
        acc, loss = evaluate(fns, base, global_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost]))))
        if verbose:
            print(f"[fedllm/spmd] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, global_lt, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 2) KD-FedLLMs
# --------------------------------------------------------------------------- #
def _batched_public_logits(kfns, base, stacked_lt, public, batch_size):
    """b2/b6 for every client at once — same batch order and original-
    row-order scatter as kd.client_logits, giving (C, N, D) with row i
    holding public sample i's logits.  Device arrays end-to-end: the b3
    compression that follows never syncs through the host."""
    outs = []
    for batch in epoch_batches(public, batch_size, seed=0,
                               drop_remainder=False):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        outs.append(kfns["batched_logits"](base, stacked_lt, jb))
    stacked = jnp.concatenate(outs, axis=1)
    perm = jnp.asarray(kd_mod._epoch_perm(len(public["tokens"]), 0))
    return jnp.zeros_like(stacked).at[:, perm].set(stacked)


def _batched_distill(kfns, base, stacked_lt, stacked_opt, public, teacher,
                     fed, batch_size, rnd, n_clients):
    """b8 for every client at once.  Clients distill against the SAME
    global knowledge over the SAME public batch order (kd.distill), so
    the per-batch step vmaps cleanly over the client axis.  Per-client
    RNG streams match the sequential backend's PRNGKey(seed + 31r + ci)."""
    rngs = jnp.stack([jax.random.PRNGKey(fed.seed + 31 * rnd + ci)
                      for ci in range(n_clients)])
    n = len(public["tokens"])
    for ep in range(fed.kd_epochs):
        perm = kd_mod._epoch_perm(n, ep)
        start = 0
        for batch in epoch_batches(public, batch_size, seed=ep,
                                   drop_remainder=False):
            sel = perm[start:start + len(batch["tokens"])]
            start += len(batch["tokens"])
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t = jnp.asarray(teacher[sel])
            rngs, subs = fed_spmd.split_each(rngs)
            stacked_lt, stacked_opt, _ = kfns["batched_kd_step"](
                base, stacked_lt, stacked_opt, jb, t, subs)
    return stacked_lt, stacked_opt


def _run_kd_spmd(model, base, cfg, fed, targets, public, clients_data,
                 test, task, batch_size, eval_batch, verbose):
    from repro.core.rounds import FedResult

    fns = make_fns(model, fed, task)
    kfns = fed_spmd.make_kd_spmd_fns(model, fed, task)
    key = jax.random.PRNGKey(fed.seed + 2)
    n_clients = len(clients_data)

    stacked_lt = fed_spmd.stack_trees(
        [lora_lib.init_lora(jax.random.fold_in(key, ci), base, targets,
                            fed.lora_rank, fed.lora_alpha)
         for ci in range(n_clients)])
    one_lt = jax.tree.map(lambda x: x[0], stacked_lt)
    stacked_opt = fed_spmd.stack_for_clients(fns["opt_init"](one_lt),
                                             n_clients)
    server_lt = lora_lib.init_lora(jax.random.fold_in(key, 999), base,
                                   targets, fed.lora_rank, fed.lora_alpha)
    server_opt = fns["opt_init"](server_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, _ = _client_weights(clients_data)
    pub_tok = public["tokens"].size
    n_lora = lora_lib.n_params(server_lt)

    for rnd in range(fed.rounds):
        # b1: vmapped local fine-tuning (params never leave the client)
        seeds = [fed.seed * 991 + rnd + ep for ep in range(fed.local_epochs)]
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, seeds)
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        stacked_lt, stacked_opt, _ = kfns["client_update"](
            base, stacked_lt, stacked_opt, batches, keys,
            jnp.asarray(valid))
        # b2: batched logit production on the public set -> (C, N, D)
        logits_cnd = _batched_public_logits(kfns, base, stacked_lt, public,
                                            eval_batch)
        # b3: per-simulated-client compression + upload accounting
        uploaded = []
        for ci in range(n_clients):
            lg, wire = kd_mod.compress_for_wire(logits_cnd[ci], fed)
            ledger.record(rnd, ci, "logits", M.UP, wire)
            uploaded.append(lg)
            cost[ci].add_train(cfg, n_tok[ci], n_lora)
            cost[ci].add_fwd(cfg, pub_tok)
        # b4: knowledge processing as a client-axis reduction (on device)
        teacher = kd_mod.aggregate_knowledge_batched(
            jnp.stack(uploaded), weights)
        # b5: server-side distillation into the global model
        server_lt, server_opt, _ = kd_mod.distill(
            fns, base, server_lt, server_opt, public, teacher,
            fed.kd_epochs, eval_batch, seed=fed.seed + rnd)
        # b6/b7: global logits back to every client (arithmetic wire size)
        glob = kd_mod.client_logits(fns, base, server_lt, public, eval_batch)
        glob_wire = kd_mod.logit_wire_bytes(glob.shape, fed)
        ledger.record_batch(rnd, "logits", M.DOWN, [glob_wire] * n_clients)
        # b8: vmapped client-side distillation
        stacked_lt, stacked_opt = _batched_distill(
            kfns, base, stacked_lt, stacked_opt, public, glob, fed,
            eval_batch, rnd, n_clients)
        for ci in range(n_clients):
            cost[ci].add_train(cfg, pub_tok * fed.kd_epochs, n_lora)
        acc, loss = evaluate(fns, base, server_lt, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost]))))
        if verbose:
            print(f"[kd/spmd] round {rnd}: acc={acc:.4f} loss={loss:.4f}")
    return FedResult(history, ledger, server_lt, [c.flops for c in cost])


# --------------------------------------------------------------------------- #
# 3) Split-FedLLMs
# --------------------------------------------------------------------------- #
def _run_split_spmd(model, base, cfg, fed, targets, public, clients_data,
                    test, task, batch_size, eval_batch, verbose):
    from repro.core.rounds import FedResult

    fns = make_fns(model, fed, task)           # for eval on the full model
    sfns = split_mod.make_split_fns(model, fed, task)
    round_step = jax.jit(fed_spmd.make_split_spmd_round(model, fed, task,
                                                        sfns=sfns))
    key = jax.random.PRNGKey(fed.seed + 3)
    n_clients = len(clients_data)
    L = sfns["n_client_groups"]
    frac_client = L / max(sfns["n_groups"], 1)

    full_lt = lora_lib.init_lora(key, base, targets, fed.lora_rank,
                                 fed.lora_alpha)
    c_global, s_lt = split_mod.split_lora(full_lt, L)
    base_c, base_s = split_mod.split_base(base, L, cfg.is_encoder_decoder)
    s_opt = sfns["opt_init"](s_lt)

    ledger, history, cost = M.CommLedger(), [], \
        [M.ClientCost() for _ in range(n_clients)]
    weights, wj = _client_weights(clients_data)
    c_bytes = M.tree_bytes(c_global)
    n_c_lora = lora_lib.n_params(c_global)
    joined = full_lt

    for rnd in range(fed.rounds):
        batches, valid, n_tok = fed_spmd.stack_client_batches(
            clients_data, batch_size, [fed.seed * 983 + rnd])
        key, sub = jax.random.split(key)
        keys = fed_spmd.split_keys(sub, n_clients, valid.shape[1])
        # wire bytes are shape-derived — identical per (client, batch)
        up, down = sfns["wire_bytes_per_batch"](batches["tokens"].shape[-2:])
        lbl = batches["labels"][0, 0].size * 4 if "labels" in batches else 0
        for ci in range(n_clients):
            ledger.record(rnd, ci, "lora_params", M.DOWN, c_bytes)   # cc3
            for _ in range(int(valid[ci].sum())):
                ledger.record(rnd, ci, "activations", M.UP, up + lbl)  # c2
                ledger.record(rnd, ci, "act_grads", M.DOWN, down)      # c4
            cost[ci].add_train(cfg, n_tok[ci], n_c_lora,
                               frac_layers=frac_client)
            ledger.record(rnd, ci, "lora_params", M.UP, c_bytes)     # cc1
        c_global, s_lt, s_opt, _ = round_step(
            base_c, base_s, c_global, s_lt, s_opt, batches, keys,
            jnp.asarray(valid), wj)
        joined = split_mod.join_lora(c_global, s_lt)
        acc, loss = evaluate(fns, base, joined, test, eval_batch)
        history.append(M.RoundMetrics(
            rnd, acc, loss, ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in cost]))))
        if verbose:
            print(f"[split/spmd] round {rnd}: acc={acc:.4f} "
                  f"loss={loss:.4f}")
    return FedResult(history, ledger, joined, [c.flops for c in cost])
