"""Knowledge/activation compression — the paper's SSIV.B.2 / SSIV.C.2
research directions, implemented as first-class features:

- top-k logit sparsification (generative KD: keep k << V predictions)
- fused top-k + int8/int4 quantization (KD b3 upload; device kernel)
- int8/int4 symmetric per-row quantization (logits, activations, grads)
  with real nibble packing for int4 — the reported wire bytes are the
  size of an actually transmittable payload
- softened-label compression (temperature + float16)
Each returns (compressed, meta) plus exact wire-size accounting, and a
``decompress`` that reconstructs the dense tensor the receiver trains on.
All paths are pure jnp or Pallas kernels — nothing bounces through host
numpy, so compression composes with jit and never forces a device sync.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_FILL = -1e9


def _n_rows(x: jax.Array) -> int:
    return math.prod(x.shape[:-1])


# --------------------------------------------------------------------------- #
# Top-k logits (SSIV.B.2)
# --------------------------------------------------------------------------- #
def topk_compress(logits: jax.Array, k: int):
    """logits (..., V) -> ({"values","indices"}, wire_bytes)."""
    vals, idx = jax.lax.top_k(logits, k)
    wire = vals.size * 4 + idx.size * 4
    return {"values": vals, "indices": idx, "dim": logits.shape[-1]}, wire


def topk_decompress(comp) -> jax.Array:
    """Reconstruct dense logits; missing entries get a large negative value
    so softmax mass matches the transmitted top-k support."""
    vals, idx = comp["values"], comp["indices"]
    shape = vals.shape[:-1] + (comp["dim"],)
    dense = jnp.full(shape, NEG_FILL, vals.dtype)
    return _scatter_last(dense, idx, vals)


def _scatter_last(dense, idx, vals):
    flat_dense = dense.reshape(-1, dense.shape[-1])
    flat_idx = idx.reshape(-1, idx.shape[-1])
    flat_vals = vals.reshape(-1, vals.shape[-1])
    rows = jnp.arange(flat_dense.shape[0])[:, None]
    out = flat_dense.at[rows, flat_idx].set(flat_vals)
    return out.reshape(dense.shape)


# --------------------------------------------------------------------------- #
# Fused top-k + int quantization (KD b3 upload — one device kernel)
# --------------------------------------------------------------------------- #
def topk_quantize(logits: jax.Array, k: int, bits: int = 8):
    """logits (..., V) -> ({"values_q","indices","scale","dim"}, wire).

    Selection + quantization stay on-device: the fused Pallas kernel
    (kernels/quantize.topk_quantize_rows) under the ``pallas`` policy,
    the bit-identical XLA reference otherwise.  The wire size is the
    packed payload: k quantized values (nibble-packed for int4) + k
    int32 indices + one fp32 scale per row."""
    assert bits in (4, 8)
    from repro.kernels import ops as kernel_ops
    q, idx, scale = kernel_ops.topk_quantize(logits, k, bits=bits)
    if bits == 4:
        q = pack_int4(q)
    rows = _n_rows(logits)
    wire = q.size + idx.size * 4 + rows * 4
    return {"values_q": q, "indices": idx, "scale": scale,
            "dim": logits.shape[-1], "k": k}, int(wire)


def topk_dequantize(comp) -> jax.Array:
    q = comp["values_q"]
    if q.dtype == jnp.uint8:                     # int4-packed
        q = unpack_int4(q, comp["k"])
    vals = q.astype(jnp.float32) * comp["scale"]
    shape = vals.shape[:-1] + (comp["dim"],)
    dense = jnp.full(shape, NEG_FILL, jnp.float32)
    return _scatter_last(dense, comp["indices"], vals)


# --------------------------------------------------------------------------- #
# int4 nibble packing (two values per byte)
# --------------------------------------------------------------------------- #
def pack_int4(q: jax.Array) -> jax.Array:
    """q int8 (..., C) with values in [-7, 7] -> uint8 (..., ceil(C/2)).

    Even column in the low nibble, odd column in the high nibble (two's
    complement); odd C is zero-padded.  The packed array is the actual
    transmittable payload — its ``size`` is what the ledger records."""
    C = q.shape[-1]
    if C % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
    u = q.astype(jnp.int32) & 0xF
    pair = u.reshape(*u.shape[:-1], -1, 2)
    return (pair[..., 0] | (pair[..., 1] << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array, C: int) -> jax.Array:
    """Inverse of ``pack_int4``: uint8 (..., P) -> int8 (..., C)."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    inter = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], -1)[..., :C]
    return jnp.where(inter > 7, inter - 16, inter).astype(jnp.int8)


# --------------------------------------------------------------------------- #
# Symmetric per-row quantization (SSIV.C.2)
# --------------------------------------------------------------------------- #
def quantize(x: jax.Array, bits: int = 8):
    """(..., d) -> ({"q"|"q4", "scale"}, wire_bytes).  Per-row absmax
    scaling (the jnp reference for kernels/quantize.py; int4 under the
    ``pallas`` policy packs in-kernel).  int4 payloads are nibble-packed
    so ``wire`` equals the payload size exactly (two values per byte +
    4-byte row scales)."""
    assert bits in (4, 8)
    if bits == 4:
        from repro.kernels import ops as kernel_ops
        if kernel_ops.use_pallas() and x.shape[-1] % 2 == 0:
            # in-kernel nibble packing: quantize + pack in one pass
            packed, scale = kernel_ops.quantize_pack4(x)
        else:
            q, scale = _quantize_jnp(x, bits)
            packed = pack_int4(q)
        return {"q4": packed, "scale": scale,
                "dim": x.shape[-1]}, quant_wire_bytes(x.shape, bits)
    q, scale = _quantize_jnp(x, bits)
    return {"q": q, "scale": scale}, quant_wire_bytes(x.shape, bits)


def quant_wire_bytes(shape, bits: int) -> int:
    """Exact transmittable size of a per-row quantized (..., d) tensor:
    nibble-packed payload (ceil per row for int4) + 4-byte row scales."""
    rows = math.prod(shape[:-1])
    return rows * ((shape[-1] * bits + 7) // 8) + rows * 4


def _quantize_jnp(x: jax.Array, bits: int):
    qmax = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(comp) -> jax.Array:
    if "q4" in comp:
        q = unpack_int4(comp["q4"], comp["dim"])
        return q.astype(jnp.float32) * comp["scale"]
    return comp["q"].astype(jnp.float32) * comp["scale"]


def quant_roundtrip(x: jax.Array, bits: int = 8):
    """Straight-through quantize->dequantize with wire-size accounting.

    Skips materializing the packed payload: the roundtrip value only
    needs the unpacked int levels (the split activation hot path runs
    this per microbatch), and the wire figure is pure arithmetic —
    identical to what ``quantize`` reports for the same tensor."""
    q, scale = _quantize_jnp(x, bits)
    deq = (q.astype(jnp.float32) * scale).astype(x.dtype)
    return deq, quant_wire_bytes(x.shape, bits)


# --------------------------------------------------------------------------- #
# Softened labels (SSIV.B.2 "knowledge compression")
# --------------------------------------------------------------------------- #
def soften(logits: jax.Array, temperature: float = 2.0):
    """Temperature-softened probabilities in fp16 (half the wire size)."""
    p = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    return p.astype(jnp.float16), p.size * 2


def soft_to_logits(soft_p: jax.Array, temperature: float = 2.0):
    """Invert to (scaled) logits for the KD loss: T * log p."""
    return temperature * jnp.log(
        jnp.maximum(soft_p.astype(jnp.float32), 1e-8))
