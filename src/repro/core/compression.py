"""Knowledge/activation compression — the paper's SSIV.B.2 / SSIV.C.2
research directions, implemented as first-class features:

- top-k logit sparsification (generative KD: keep k << V predictions)
- int8/int4 symmetric per-row quantization (logits, activations, grads)
- softened-label compression (temperature + float16)
Each returns (compressed, meta) plus exact wire-size accounting, and a
``decompress`` that reconstructs the dense tensor the receiver trains on.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_FILL = -1e9


# --------------------------------------------------------------------------- #
# Top-k logits (SSIV.B.2)
# --------------------------------------------------------------------------- #
def topk_compress(logits: jax.Array, k: int):
    """logits (..., V) -> ({"values","indices"}, wire_bytes)."""
    vals, idx = jax.lax.top_k(logits, k)
    wire = vals.size * 4 + idx.size * 4
    return {"values": vals, "indices": idx, "dim": logits.shape[-1]}, wire


def topk_decompress(comp) -> jax.Array:
    """Reconstruct dense logits; missing entries get a large negative value
    so softmax mass matches the transmitted top-k support."""
    vals, idx = comp["values"], comp["indices"]
    shape = vals.shape[:-1] + (comp["dim"],)
    dense = jnp.full(shape, NEG_FILL, vals.dtype)
    return _scatter_last(dense, idx, vals)


def _scatter_last(dense, idx, vals):
    flat_dense = dense.reshape(-1, dense.shape[-1])
    flat_idx = idx.reshape(-1, idx.shape[-1])
    flat_vals = vals.reshape(-1, vals.shape[-1])
    rows = jnp.arange(flat_dense.shape[0])[:, None]
    out = flat_dense.at[rows, flat_idx].set(flat_vals)
    return out.reshape(dense.shape)


# --------------------------------------------------------------------------- #
# Symmetric per-row quantization (SSIV.C.2)
# --------------------------------------------------------------------------- #
def quantize(x: jax.Array, bits: int = 8):
    """(..., d) -> ({"q", "scale"}, wire_bytes).  Per-row absmax scaling.
    The pure-jnp reference for kernels/quantize.py."""
    assert bits in (4, 8)
    qmax = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    q = q.astype(jnp.int8)
    n_rows = 1
    for s in x.shape[:-1]:
        n_rows *= s
    wire = x.size * bits // 8 + n_rows * 4          # payload + row scales
    return {"q": q, "scale": scale.astype(jnp.float32)}, int(wire)


def dequantize(comp) -> jax.Array:
    return comp["q"].astype(jnp.float32) * comp["scale"]


def quant_roundtrip(x: jax.Array, bits: int = 8):
    """Straight-through quantize->dequantize with wire-size accounting."""
    comp, wire = quantize(x, bits)
    return dequantize(comp).astype(x.dtype), wire


# --------------------------------------------------------------------------- #
# Softened labels (SSIV.B.2 "knowledge compression")
# --------------------------------------------------------------------------- #
def soften(logits: jax.Array, temperature: float = 2.0):
    """Temperature-softened probabilities in fp16 (half the wire size)."""
    p = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    return p.astype(jnp.float16), p.size * 2


def soft_to_logits(soft_p: jax.Array, temperature: float = 2.0):
    """Invert to (scaled) logits for the KD loss: T * log p."""
    return temperature * jnp.log(
        jnp.maximum(soft_p.astype(jnp.float32), 1e-8))
