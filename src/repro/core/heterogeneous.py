"""Heterogeneous-rank LoRA aggregation (paper SSIV.A.2 — beyond-paper
feature): clients fine-tune with different ranks matched to their
resources; the server harmonizes scales before aggregation.

Two strategies:
- ``zeropad``: pad every client's A/B to the max rank, weighted FedAvg in
  factor space (exact when B==0 columns stay untouched; the standard
  HETLoRA baseline).
- ``svd``: reconstruct each client's *delta* (alpha/r_c * A_c @ B_c),
  average the deltas (the quantity that actually edits the model), then
  SVD-truncate back to the global rank — scale-exact at the cost of an
  SVD per target matrix.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.fedavg import fedavg
from repro.peft import lora as lora_lib


def normalize_ranks(client_ranks, n_clients: int,
                    lora_rank: int) -> List[int]:
    """Single source of truth for per-client LoRA rank normalization:
    an empty/None ``client_ranks`` means every client trains at the
    global rank; otherwise the tuple must name every client exactly once
    and stay within [1, lora_rank].  Every rank-dependent code path
    (engines, bucketing, harmonization) starts from this list — the
    degenerate configs (wrong length, all-equal ranks collapsing to one
    bucket) are property-tested in tests/test_property.py."""
    if not client_ranks:
        return [lora_rank] * n_clients
    if len(client_ranks) != n_clients:
        raise ValueError(
            f"client_ranks has {len(client_ranks)} entries for "
            f"{n_clients} clients")
    if any(r < 1 or r > lora_rank for r in client_ranks):
        raise ValueError(
            f"client_ranks must lie in [1, lora_rank={lora_rank}] "
            f"(got {tuple(client_ranks)}); weak clients truncate the "
            "global rank, they never exceed it")
    return list(client_ranks)


def aggregate_hetero(trees: List, ranks: Sequence[int], alpha: float,
                     global_rank: int, weights=None, method: str = "zeropad"):
    if method == "zeropad":
        padded = [lora_lib.pad_rank(t, global_rank) for t in trees]
        return fedavg(padded, weights)
    if method == "svd":
        return _svd_aggregate(trees, ranks, alpha, global_rank, weights)
    raise ValueError(method)


def harmonize_buckets(bucket_trees, bucket_clients, ranks: Sequence[int],
                      alpha: float, global_rank: int, weights,
                      method: str = "zeropad"):
    """Cross-bucket harmonization for the SPMD backend's per-rank
    bucketing (core/rounds_spmd.py): ``bucket_trees[k]`` is a list of
    per-client LoRA trees for the clients in ``bucket_clients[k]``.
    Reassembles all clients into visit order and runs the same
    ``aggregate_hetero`` the sequential backend uses, so both backends
    share one harmonization code path."""
    by_client = {}
    for trees, clients in zip(bucket_trees, bucket_clients):
        for ci, t in zip(clients, trees):
            by_client[ci] = t
    order = sorted(by_client)
    return aggregate_hetero([by_client[ci] for ci in order],
                            [ranks[ci] for ci in order], alpha, global_rank,
                            [weights[ci] for ci in order], method)


def _svd_aggregate(trees, ranks, alpha, global_rank, weights):
    if weights is None:
        weights = [1.0] * len(trees)
    total = float(sum(weights))
    ws = [w / total for w in weights]
    scale_g = alpha / max(global_rank, 1)

    def combine(*leaves):
        # leaves: one {"a","b"} dict per client
        delta = None
        for w, lf, r in zip(ws, leaves, ranks):
            s = alpha / max(r, 1)
            d = jnp.einsum("...dr,...rf->...df",
                           lf["a"].astype(jnp.float32),
                           lf["b"].astype(jnp.float32)) * (s * w)
            delta = d if delta is None else delta + d
        u, vt = lora_lib.svd_truncate(delta / scale_g, global_rank)
        return {"a": u, "b": vt}

    return _map_lora_leaves(combine, *trees)


def _map_lora_leaves(fn, *trees):
    t0 = trees[0]
    if isinstance(t0, dict) and set(t0) == {"a", "b"}:
        return fn(*trees)
    if isinstance(t0, dict):
        return {k: _map_lora_leaves(fn, *[t[k] for t in trees]) for k in t0}
    if isinstance(t0, (tuple, list)):
        return tuple(
            _map_lora_leaves(fn, *[t[i] for t in trees])
            if t0[i] is not None else None
            for i in range(len(t0)))
    return t0
