"""FedLLMs — the paper's foundational framework (SSII.A):

    a1 server -> clients: global tunable (LoRA) parameters
    a2 client: local PEFT fine-tuning on private data
    a3 clients -> server: fine-tuned tunable parameters
    a4 server: aggregation (FedAvg) -> next global parameters

This module also provides the jitted train/eval/logit steps shared by all
three frameworks (they differ in *what* is exchanged, not in how a local
step runs).  The base model is a closed-over constant of the loss, so
gradients exist only for the LoRA tree — the PEFT property (paper fn.1).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, ModelConfig
from repro.core import tasks
from repro.models.factory import Model
from repro.optim.api import make_optimizer
from repro.peft import lora as lora_lib


def make_fns(model: Model, fed: FedConfig, task: str = "classification"):
    """Returns dict of jitted fns: train_step, eval_step, logits_fn,
    kd_step (distill to teacher logits).

    Every returned step enters the model's kernel-policy scope
    (kernels/ops.policy_scope) for its whole body, so parts of a step
    outside Model.forward — e.g. the KD loss in kd_step — dispatch to
    the same kernels as the forward even when the step is called
    directly rather than through core/rounds.run_federated."""
    cfg = model.cfg
    task_loss = tasks.get_loss_fn(task)
    opt_init, opt_update = make_optimizer(fed.optimizer)

    from repro.kernels import ops as kernel_ops

    def _scoped(fn):
        @functools.wraps(fn)
        def call(*args, **kwargs):
            with kernel_ops.policy_scope(cfg.kernel_policy):
                return fn(*args, **kwargs)
        return call

    def _bind(base, lt, rng=None):
        rank = lora_lib.tree_rank(lt, fed.lora_rank)
        return lora_lib.bind(base, lt, fed.lora_alpha, rank,
                             dropout_mask_rng=rng, dropout=fed.lora_dropout)

    priv = fed.privacy

    def train_step_impl(base, lt, opt_state, batch, rng):
        """Raw (un-jitted) local step — also scanned/vmapped by the SPMD
        backend (core/fed_spmd.py), so both backends share ONE loss.

        With ``PrivacyConfig.dp_clip > 0`` this is a DP-SGD step: the
        stacked per-example gradients are clipped to L2 norm C and
        averaged through the fused clip-scale-accumulate kernel
        (privacy/dp.clipped_grad_mean) before the optimizer update —
        deterministic, so the backends stay in parity for free.  The
        seeded payload noise lives at the upload boundary, not here."""
        def loss_fn(l):
            bound = _bind(base, l, rng)
            logits, aux = model.forward(bound, batch)
            loss, _ = task_loss(logits, batch)
            return loss + aux

        if priv.dp_clip > 0.0:
            from repro.privacy import dp as dp_mod

            def example_loss(l, example):
                one = jax.tree.map(lambda x: x[None], example)
                bound = _bind(base, l, rng)
                logits, aux = model.forward(bound, one)
                loss, _ = task_loss(logits, one)
                return loss + aux

            losses, per_ex = jax.vmap(
                jax.value_and_grad(example_loss),
                in_axes=(None, 0))(lt, batch)
            grads = dp_mod.clipped_grad_mean(per_ex, priv.dp_clip)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(lt)
        new_lt, new_opt = opt_update(grads, opt_state, lt, fed.lr)
        # metric-only guard: a corrupted/diverged batch must not poison
        # the round's accumulated mean loss (the params still move and
        # the upload-seam validation screens the payload itself)
        loss = jnp.where(jnp.isfinite(loss), loss, 0.0)
        return new_lt, new_opt, loss

    train_step = jax.jit(train_step_impl)

    @jax.jit
    def eval_step(base, lt, batch):
        bound = _bind(base, lt)
        logits, _ = model.forward(bound, batch)
        if task == "classification":
            acc = tasks.classification_accuracy(logits, batch)
        else:
            acc = -task_loss(logits, batch)[0]
        loss, _ = task_loss(logits, batch)
        return acc, loss

    @jax.jit
    def logits_fn(base, lt, batch):
        """Knowledge representation for KD (paper b2/b6): class logits for
        classification, full LM logits for generative tasks."""
        bound = _bind(base, lt)
        logits, _ = model.forward(bound, batch)
        if task == "classification":
            return tasks.class_logits(logits, batch)
        return logits

    @jax.jit
    def kd_step(base, lt, opt_state, batch, teacher_logits, rng):
        """Distill ``teacher_logits`` into the student's LoRA params."""
        from repro.models import loss as losses

        def loss_fn(l):
            bound = _bind(base, l, rng)
            logits, aux = model.forward(bound, batch)
            if task == "classification":
                student = tasks.class_logits(logits, batch)
            else:
                student = logits
            return losses.kd_kl(student, teacher_logits,
                                fed.kd_temperature) + aux

        loss, grads = jax.value_and_grad(loss_fn)(lt)
        new_lt, new_opt = opt_update(grads, opt_state, lt, fed.lr)
        return new_lt, new_opt, loss

    return {"train_step": _scoped(train_step),
            "train_step_impl": train_step_impl,
            "eval_step": _scoped(eval_step),
            "logits_fn": _scoped(logits_fn),
            "kd_step": _scoped(kd_step), "opt_init": opt_init,
            "opt_update": opt_update, "bind": _bind}


# --------------------------------------------------------------------------- #
# Aggregation (a4)
# --------------------------------------------------------------------------- #
def fedavg(trees: Sequence, weights: Optional[Sequence[float]] = None):
    """Weighted FedAvg of identically-structured pytrees."""
    if weights is None:
        weights = [1.0] * len(trees)
    total = float(sum(weights))
    # fully-dropped cohort: fall back to a uniform mean rather than 0/0
    ws = [w / total for w in weights] if total > 0 \
        else [1.0 / len(trees)] * len(trees)

    def mean(*leaves):
        out = leaves[0].astype(jnp.float32) * ws[0]
        for w, leaf in zip(ws[1:], leaves[1:]):
            out = out + leaf.astype(jnp.float32) * w
        return out.astype(leaves[0].dtype)

    return jax.tree.map(mean, *trees)


def evaluate(fns, base, lt, data: Dict, batch_size: int = 64) -> tuple:
    """Mean accuracy/loss over a dataset."""
    from repro.data.loader import epoch_batches
    accs, losses_, n = [], [], 0
    for batch in epoch_batches(data, batch_size, seed=0):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        a, l = fns["eval_step"](base, lt, jb)
        accs.append(float(a) * len(batch["tokens"]))
        losses_.append(float(l) * len(batch["tokens"]))
        n += len(batch["tokens"])
    if n == 0:
        return 0.0, 0.0
    return sum(accs) / n, sum(losses_) / n
