"""Communication- and computation-accounting — the paper's evaluation
axes (SSIII, Figs. 3-4, Table I), measured by the framework itself.

Every server<->client exchange goes through a ``CommLedger`` so the
per-client per-round bytes of Fig. 4 fall out of the run, and client-side
FLOPs are derived from the architecture config with the standard
transformer estimates (6ND train, 2ND forward; PEFT backward ~ 4ND since
frozen-weight grads are skipped but activation grads still chain)."""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax

from repro.configs.base import FedConfig, ModelConfig

UP = "up"          # client -> server
DOWN = "down"      # server -> client

# Hops of the aggregation topology.  The flat (single-hop) engines
# record everything on ``client_server``; the hierarchical path of the
# cohort-streaming executor records per-client traffic on
# ``client_edge`` (same names, same shape-derived bytes — Fig. 4's
# per-client accounting is hop-invariant by construction) plus per-edge
# ``edge_server`` aggregate/broadcast events, so the two-hop topology's
# wire cost is reported separately per hop.
CLIENT_SERVER = "client_server"
CLIENT_EDGE = "client_edge"
EDGE_SERVER = "edge_server"

# Ledger event names that are privacy *overhead* rather than model
# payload: secure-agg key/share exchange, dropout-recovery shares, and
# per-release DP metadata (clip bound, noise scale, seed id).  Fig. 4's
# privacy-overhead column and the bit-exactness tests filter on these.
PRIVACY_NAMES = ("secagg_keys", "secagg_recovery", "dp_meta")
# Edge-infrastructure event names (hierarchical aggregation overlay):
# like PRIVACY_NAMES these are topology overhead, not client payload,
# and parity comparisons filter them via ``payload_view``.
EDGE_NAMES = ("edge_agg",)
# Fault-tolerance accounting (src/repro/faults/ + the round driver's
# validation middleware): ``quarantine`` — an arrival the validator
# rejected (non-finite or norm-screened payload; the bytes crossed the
# wire but never reached the aggregate), ``retransmit`` — an upload a
# FaultPlan dropout lost in transit (wasted upstream bytes the client
# must re-send).  Like PRIVACY_NAMES/EDGE_NAMES these are overhead, not
# model payload, and ``payload_view`` filters them.
FAULT_NAMES = ("quarantine", "retransmit")
DP_META_BYTES = 12   # fp32 clip + fp32 sigma + int32 stream id


@dataclasses.dataclass
class CommEvent:
    round: int
    client: int          # negative ids denote edge aggregators
    name: str            # e.g. "lora_params", "logits", "activations"
    direction: str
    bytes: int
    hop: str = CLIENT_SERVER


class CommLedger:
    def __init__(self):
        self.events: List[CommEvent] = []
        # hop stamped on records that don't name one — the streaming
        # driver flips this to CLIENT_EDGE under hierarchical
        # aggregation so every stage hook reports the right hop without
        # per-program threading
        self.default_hop = CLIENT_SERVER

    def record(self, rnd: int, client: int, name: str, direction: str,
               nbytes: int, hop: Optional[str] = None):
        self.events.append(CommEvent(rnd, client, name, direction,
                                     int(nbytes),
                                     hop or self.default_hop))

    def record_batch(self, rnd: int, name: str, direction: str,
                     client_bytes: "List[int]"):
        """One batched SPMD exchange: element i is client i's payload.
        Wire sizes stay per-simulated-client so Fig. 4 reads identically
        from either execution backend."""
        for ci, nbytes in enumerate(client_bytes):
            self.record(rnd, ci, name, direction, nbytes)

    def record_bucket(self, rnd: int, clients: "List[int]", name: str,
                      direction: str, nbytes_each: int):
        """One bucketed SPMD exchange: every client in a per-rank bucket
        moves the same (rank-dependent) payload.  Bytes stay
        per-simulated-client, so heterogeneous runs report Fig. 4
        identically from either execution backend."""
        for ci in clients:
            self.record(rnd, ci, name, direction, nbytes_each)

    # -- queries ---------------------------------------------------------
    def total(self, direction: Optional[str] = None) -> int:
        return sum(e.bytes for e in self.events
                   if direction is None or e.direction == direction)

    def per_client_round(self) -> Dict[tuple, int]:
        out = collections.defaultdict(int)
        for e in self.events:
            out[(e.round, e.client)] += e.bytes
        return dict(out)

    def per_round(self) -> Dict[int, int]:
        out = collections.defaultdict(int)
        for e in self.events:
            out[e.round] += e.bytes
        return dict(out)

    def by_name(self) -> Dict[str, int]:
        out = collections.defaultdict(int)
        for e in self.events:
            out[e.name] += e.bytes
        return dict(out)

    def mean_client_bytes_per_round(self) -> float:
        # edge aggregators (negative ids) are infrastructure, not
        # clients — Fig. 4's per-client mean excludes their traffic
        pcr = {k: v for k, v in self.per_client_round().items()
               if k[1] >= 0}
        return sum(pcr.values()) / max(len(pcr), 1)

    def privacy_overhead_bytes(self) -> int:
        """Total wire bytes spent on the privacy machinery itself."""
        return sum(e.bytes for e in self.events if e.name in PRIVACY_NAMES)

    def payload_events(self) -> "List[CommEvent]":
        """Events net of privacy overhead — what the non-private engines
        would have recorded (the bit-exactness comparison surface)."""
        return [e for e in self.events if e.name not in PRIVACY_NAMES]

    def fault_overhead_bytes(self) -> int:
        """Wire bytes wasted on faults: quarantined and lost uploads."""
        return sum(e.bytes for e in self.events if e.name in FAULT_NAMES)

    # -- hop accounting (hierarchical aggregation) ----------------------- #
    def by_hop(self, direction: Optional[str] = None) -> Dict[str, int]:
        out = collections.defaultdict(int)
        for e in self.events:
            if direction is None or e.direction == direction:
                out[e.hop] += e.bytes
        return dict(out)

    def hop_total(self, hop: str, direction: Optional[str] = None) -> int:
        return sum(e.bytes for e in self.events if e.hop == hop
                   and (direction is None or e.direction == direction))

    def payload_view(self) -> "CommLedger":
        """A ledger holding only model-payload events — privacy AND
        edge-infrastructure overhead filtered out.  The comparison
        surface for executor golden parity: the cohort-streaming /
        hierarchical paths must report the same per-client payload
        bytes as the flat engines, whatever extra overhead categories
        they add."""
        view = CommLedger()
        view.events = [e for e in self.events
                       if e.name not in PRIVACY_NAMES + EDGE_NAMES
                       + FAULT_NAMES]
        return view


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


# --------------------------------------------------------------------------- #
# Analytic FLOPs (client-side computation, Fig. 4 right axis)
# --------------------------------------------------------------------------- #
def fwd_flops(cfg: ModelConfig, n_tokens: int,
              frac_layers: float = 1.0) -> float:
    """2 * N_active * D; ``frac_layers`` scales for split sub-models."""
    return 2.0 * cfg.active_param_count() * frac_layers * n_tokens


def train_flops(cfg: ModelConfig, n_tokens: int, peft: bool = True,
                n_peft_params: int = 0, frac_layers: float = 1.0) -> float:
    """Full FT: 6ND.  PEFT: fwd 2ND + activation-grad chain 2ND + PEFT
    weight grads (6 * n_peft * D) — frozen base weight-grads skipped."""
    base = cfg.active_param_count() * frac_layers
    if not peft:
        return 6.0 * base * n_tokens
    return (4.0 * base + 6.0 * n_peft_params) * n_tokens


@dataclasses.dataclass
class ClientCost:
    """Accumulated per-client computation."""
    flops: float = 0.0

    def add_train(self, cfg, n_tokens, n_peft, frac_layers=1.0):
        self.flops += train_flops(cfg, n_tokens, True, n_peft, frac_layers)

    def add_fwd(self, cfg, n_tokens, frac_layers=1.0):
        self.flops += fwd_flops(cfg, n_tokens, frac_layers)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    accuracy: float
    loss: float
    comm_bytes_per_client: float
    client_flops: float
    # DP epsilon spent so far at the configured PrivacyConfig.dp_delta
    # (privacy/accountant.py).  0.0 = DP not enabled (no accounting, no
    # claim); inf = clipping active without noise (no guarantee).
    epsilon: float = 0.0


def logit_bytes(n_samples: int, logit_dim: int, topk: int = 0,
                quant_bits: int = 0) -> int:
    """Communication size of a logit set (paper SSIII.B: classification vs
    generative task dimensionality; SSIV.B.2 compression options).
    Sub-byte payloads are nibble-packed per row (ceil), matching
    core/compression's actual wire payloads."""
    if topk and quant_bits:
        # fused top-k + int quantization: packed values + indices + scale
        per = (topk * quant_bits + 7) // 8 + topk * 4 + 4
    elif topk:
        per = topk * (4 + 4)                       # value + index
    elif quant_bits:
        per = (logit_dim * quant_bits + 7) // 8 + 4    # + per-row scale
    else:
        per = logit_dim * 4
    return n_samples * per
