"""Dynamic tunable-parameter schedules (paper SSIV.A.3): grow the LoRA
rank across rounds — cheap early rounds, capacity when it matters."""
from __future__ import annotations

from typing import Sequence


def rank_schedule(round_idx: int, total_rounds: int,
                  ranks: Sequence[int] = (2, 4, 8)) -> int:
    """Staircase rank growth over training."""
    stage = min(len(ranks) - 1,
                round_idx * len(ranks) // max(total_rounds, 1))
    return ranks[stage]


def grow_lora(lt, new_rank: int):
    """Zero-pad an existing LoRA tree to a larger rank (warm-start growth;
    preserves the current delta exactly since padded B rows are zero)."""
    from repro.peft import lora as lora_lib
    return lora_lib.pad_rank(lt, new_rank)
