"""Task heads: intent classification (paper case study) and generative LM.

Classification-as-LM: class c's logit is the LM logit of vocab id 1+c at
the last non-pad position (banking77.py reserves ids [1, 78) as answer
tokens) — matching GPT-2 classification fine-tuning in the paper."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.data.banking77 import N_CLASSES
from repro.models import loss as losses


def class_logits(logits: jax.Array, batch: Dict) -> jax.Array:
    """logits: (B, S', V) -> (B, n_classes) at the last non-pad position."""
    offset = logits.shape[1] - batch["tokens"].shape[1]   # vlm/prompt prefix
    pos = offset + batch["lengths"].astype(jnp.int32) - 1  # (B,)
    g = jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]
    return g[:, 1:1 + N_CLASSES]


def classification_loss_fn(logits, batch):
    cl = class_logits(logits, batch)
    loss, _ = losses.cross_entropy(cl, batch["labels"])
    return loss, cl


def classification_accuracy(logits, batch) -> jax.Array:
    cl = class_logits(logits, batch)
    return losses.accuracy(cl, batch["labels"])


def generative_loss_fn(logits, batch):
    mask = (batch["tokens"] != 0).astype(jnp.float32)
    offset = logits.shape[1] - batch["tokens"].shape[1]
    lg = logits[:, offset:]
    loss, _ = losses.next_token_loss(lg, batch["tokens"], mask)
    return loss, lg


def task_logit_dim(task: str, vocab_size: int) -> int:
    """Paper SSIII.B: classification logits ~ n_classes; generative ~ V."""
    return N_CLASSES if task == "classification" else vocab_size


def get_loss_fn(task: str):
    return (classification_loss_fn if task == "classification"
            else generative_loss_fn)
