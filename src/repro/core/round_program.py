"""The composable federated round pipeline — ONE driver for every
engine combination the paper's comparison needs:

    framework     x   backend      x   aggregation   (+ privacy, hetero)
    fedllm/kd/split   sequential/spmd  sync/async

One federated round decomposes into the paper's canonical stages

    broadcast -> local_update -> upload -> aggregate -> evaluate

and the combination axes are orthogonal pieces composed by
``run_program``:

- A **FrameworkProgram** (FedLLM / KD / Split) contributes the stage
  bodies: what a client computes, what crosses the wire (payload +
  shape-derived bytes), and how the server fuses arrivals.  The same
  stage-specs hand the launch layer its jittable round programs
  (``FrameworkProgram.spmd_round`` — launch/steps.py builds the
  ``fed_round`` dry-run artifacts from them).
- An **Executor** decides how per-client work runs.  ``sequential``
  loops clients (the paper-literal reference); ``spmd`` stacks the
  round's ready-set on a leading client axis and runs one jitted
  program per rank bucket (contiguous equal-rank segments for Split,
  whose shared server half scans clients in visit order).  Given a
  mesh, the SPMD executor places the stacked client axis on the mesh's
  client axes with explicit NamedShardings (launch/sharding.py) — the
  client dimension of a real run shards over the pod/data axes, not
  just in the dry-run.  ``cohort`` (CohortStreamingExecutor) is the
  million-virtual-client path: the round's ready set streams through
  the same SPMD stage programs ``FedConfig.cohort_size`` clients at a
  time, jitted donated-buffer folds carry the partial aggregates
  (weighted param/logit sums, ledger counters, per-chunk secure-agg
  cohorts) between chunks, and clients come from a lazy
  ``data/population.ClientPopulation`` — peak memory is ONE cohort, no
  full-fleet array ever exists.  Under a hierarchical topology
  (``FedConfig.n_edges`` or a multi-pod mesh) the ledger splits wire
  accounting into client->edge and edge->server hops.
- A **Schedule** decides when uploads arrive: ``SyncSchedule`` delivers
  in the start round; ``AsyncSchedule`` wraps the seeded
  ``ParticipationSchedule`` delay model (core/async_agg.py) and the
  aggregate stage folds arrivals in staleness-weighted.
  ``max_staleness == 0`` collapses async onto sync exactly.
- **Privacy is middleware at fixed seams**: per-example DP-SGD clipping
  lives inside the shared train step (core/fedavg.py), payload noise is
  applied at the upload boundary from the dedicated fold_in stream
  (privacy/dp.py), and secure aggregation masks at upload / verifies
  cancellation at aggregate — uniformly, with zero per-driver
  threading.

Ledger bytes are derived from payload shapes on the host, so they are
per-simulated-client and backend-independent by construction
(tests/test_backend_parity.py pins the full engine matrix).

Adding a framework is one FrameworkProgram subclass; adding a
cross-cutting feature is one stage hook or middleware — not an edit to
O(frameworks x backends x aggregation) hand-written drivers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, ModelConfig
from repro.core import fed_spmd
from repro.core import kd as kd_mod
from repro.core import metrics as M
from repro.core import rng as rng_mod
from repro.core import split as split_mod
from repro.core.fedavg import evaluate, make_fns
from repro.core.heterogeneous import normalize_ranks
from repro.data import population as population_mod
from repro.data.loader import epoch_batches
from repro.peft import lora as lora_lib
from repro.privacy import dp as dp_mod
from repro.privacy.secure_agg import SecureAggSession


@dataclasses.dataclass
class FedResult:
    history: List[M.RoundMetrics]
    ledger: M.CommLedger
    final_lora: Dict
    client_flops: List[float]
    # rounds that failed the participation quorum and rolled over with
    # the global state unchanged (fault tolerance; 0 without a quorum)
    rollovers: int = 0

    @property
    def final_accuracy(self) -> float:
        return self.history[-1].accuracy if self.history else 0.0


def _to_jax(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


# --------------------------------------------------------------------------- #
# Privacy accounting (RDP accountant wiring)
# --------------------------------------------------------------------------- #
def make_accountant(fed: FedConfig, sample_rate: float = 1.0):
    """RDP accountant for the run, or None when DP is off entirely.

    ``sample_rate`` is the per-step subsampling rate q the engines
    report (batch_size / |local data|, worst case over clients) — the
    accountant applies subsampling amplification when q < 1.  A
    clipping-only run (dp_clip > 0, noise 0) gets an accountant whose
    epsilon is ``inf`` — the mechanism is active but offers no
    (eps, delta) guarantee, and reporting 0.0 would claim the strongest
    one instead."""
    if not fed.privacy.dp_enabled:
        return None
    from repro.privacy.accountant import GaussianAccountant
    return GaussianAccountant(fed.privacy.dp_noise_multiplier,
                              fed.privacy.dp_delta,
                              sample_rate=sample_rate)


def round_epsilon(acct, releases: int) -> float:
    """eps at the configured dp_delta after ``releases`` noisy uploads
    per client; 0.0 when DP is not enabled (no accounting, no claim),
    inf when clipping runs without noise."""
    return acct.epsilon(releases) if acct is not None else 0.0


def sample_rate(clients_data: List[Dict], batch_size: int) -> float:
    """Worst-case (largest) per-step subsampling rate over clients:
    q_i = batch_size / |client i's data|, clamped to 1."""
    return max(min(1.0, batch_size / max(len(d["tokens"]), 1))
               for d in clients_data)


# --------------------------------------------------------------------------- #
# Round context: everything the stages share
# --------------------------------------------------------------------------- #
class RoundContext:
    """Run-wide state threaded through every stage: config, data, the
    shared jitted steps, the metrics ledger/cost model, and the privacy
    middleware (accountant + secure-agg session + per-client release
    counters)."""

    def __init__(self, model, base, cfg: ModelConfig, fed: FedConfig,
                 targets, public, clients_data, test, task, batch_size,
                 eval_batch, verbose):
        self.model, self.base, self.cfg, self.fed = model, base, cfg, fed
        self.targets, self.public, self.test = targets, public, test
        # clients_data is a ClientPopulation (eager lists are wrapped at
        # the run_program boundary): indexable/len-able like the old
        # lists, but a lazy population materializes a shard only when a
        # stage actually touches ``clients_data[ci]``
        self.clients_data = population_mod.as_population(clients_data)
        self.task = task
        self.batch_size, self.eval_batch = batch_size, eval_batch
        self.verbose = verbose
        self.n_clients = len(self.clients_data)
        self.fns = make_fns(model, fed, task)
        self.ranks = normalize_ranks(fed.client_ranks, self.n_clients,
                                     fed.lora_rank)
        self.ledger = M.CommLedger()
        self.history: List[M.RoundMetrics] = []
        self.cost = [M.ClientCost() for _ in range(self.n_clients)]
        # per-client sample counts WITHOUT materializing shards (the
        # population knows its weights; for eager lists this is exactly
        # the old [len(d["tokens"]) for d in clients_data])
        self.data_w = self.clients_data.data_weights()
        self.total_w = float(sum(self.data_w))
        # worst-case subsampling rate from the weights — the arithmetic
        # twin of ``sample_rate`` that never touches client data
        self.acct = make_accountant(
            fed, max(min(1.0, batch_size / max(w, 1))
                     for w in self.data_w))
        self.secagg = SecureAggSession(fed)
        self.releases = [0] * self.n_clients   # noisy uploads per client
        # (rnd, ci) -> secure-agg masking-cohort id, populated by the
        # streaming driver (per-chunk cohorts); empty under the flat
        # engines, where the masking cohort is keyed by the start round
        self._cohort_ids: Dict[tuple, int] = {}

    def secagg_start(self, rnd: int, ci: int) -> int:
        """The secure-agg cohort key for client ``ci``'s job started in
        ``rnd`` — the per-chunk cohort id under cohort streaming, the
        start round itself (identity) everywhere else."""
        return self._cohort_ids.get((rnd, ci), rnd)


# --------------------------------------------------------------------------- #
# Schedules: when does an upload arrive at the server?
# --------------------------------------------------------------------------- #
class SyncSchedule:
    """The paper-literal parameter-server round: every client starts a
    job each round and its upload arrives the same round."""

    def __init__(self, fed: FedConfig, n_clients: int):
        self.n = n_clients
        self._pending = []

    def starters(self, rnd: int) -> List[int]:
        return list(range(self.n))

    def submit(self, rnd: int, ci: int, payload, extra_delay: int = 0):
        """``extra_delay`` is a fault-injected straggler lag: the upload
        arrives that many rounds late and flows through the staleness
        weighting like any async arrival."""
        from repro.core.async_agg import _Job
        self._pending.append(_Job(ci, rnd, rnd + extra_delay, payload))

    def pop_arrivals(self, rnd: int):
        out = sorted((j for j in self._pending if j.arrival == rnd),
                     key=lambda j: j.client)
        self._pending = [j for j in self._pending if j.arrival != rnd]
        return out

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def jobs(self):
        return list(self._pending)

    def load_jobs(self, jobs):
        self._pending = list(jobs)

    def rng_state(self):
        return None

    def load_rng_state(self, state):
        pass


class AsyncSchedule:
    """FedAsync-style participation: a free client starts a job (pulls
    the current global, trains NOW) and the upload goes in flight for a
    seeded per-job delay (core/async_agg.ParticipationSchedule)."""

    def __init__(self, fed: FedConfig, n_clients: int):
        from repro.core.async_agg import ParticipationSchedule
        self.n = n_clients
        self.sched = ParticipationSchedule(n_clients, fed.seed + 17,
                                           fed.max_staleness)
        self.in_flight: Dict[int, object] = {}

    def starters(self, rnd: int) -> List[int]:
        return [ci for ci in range(self.n) if ci not in self.in_flight]

    def submit(self, rnd: int, ci: int, payload, extra_delay: int = 0):
        from repro.core.async_agg import _Job
        self.in_flight[ci] = _Job(
            ci, rnd, rnd + self.sched.next_delay(ci) + extra_delay, payload)

    def pop_arrivals(self, rnd: int):
        from repro.core.async_agg import _pop_arrivals
        return _pop_arrivals(self.in_flight, rnd)

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def jobs(self):
        return [self.in_flight[ci] for ci in sorted(self.in_flight)]

    def load_jobs(self, jobs):
        self.in_flight = {j.client: j for j in jobs}

    def rng_state(self):
        return self.sched.state()

    def load_rng_state(self, state):
        self.sched.load_state(state)


def make_schedule(fed: FedConfig, n_clients: int):
    return (SyncSchedule if fed.aggregation == "sync"
            else AsyncSchedule)(fed, n_clients)


# --------------------------------------------------------------------------- #
# Executors: how per-client work runs
# --------------------------------------------------------------------------- #
class SequentialExecutor:
    """Python loop over clients, one jitted step per batch — the
    paper-literal reference and the numerical ground truth."""

    backend = "sequential"
    streaming = False

    def __init__(self, ctx: RoundContext, mesh=None):
        self.ctx = ctx                      # mesh ignored: nothing stacked

    # -- shared local fine-tune body (FedLLM a2 / KD b1) ----------------- #
    def _local_finetune(self, program, ci, lt, opt, rnd):
        """One client's epochs of jitted train steps; returns
        (lt, opt, n_tok).  The single loop both the FedLLM and KD
        stages call, so a change to the local update (seed formula,
        privacy hook, ...) can never apply to one framework only."""
        ctx, fed, fns = self.ctx, self.ctx.fed, self.ctx.fns
        r = rng_mod.local_rng(fed, rnd, ci)
        n_tok = 0
        for ep in range(fed.local_epochs):
            for batch in epoch_batches(
                    ctx.clients_data[ci], ctx.batch_size,
                    seed=fed.seed * program.epoch_seed_mult + rnd + ep):
                r, sub = jax.random.split(r)
                lt, opt, _ = fns["train_step"](ctx.base, lt, opt,
                                               _to_jax(batch), sub)
                n_tok += batch["tokens"].size
        return lt, opt, n_tok

    # -- FedLLM a2 ------------------------------------------------------ #
    def train(self, program, jobs, rnd):
        """jobs: [(ci, lt)] -> [(new_lt, n_tok)] in job order."""
        out = []
        for ci, lt in jobs:
            lt, _, n_tok = self._local_finetune(
                program, ci, lt, self.ctx.fns["opt_init"](lt), rnd)
            out.append((lt, n_tok))
        return out

    # -- KD b1 + b2 ----------------------------------------------------- #
    def kd_train_and_logits(self, program, cis, rnd):
        ctx = self.ctx
        out = []
        for ci in cis:
            lt, opt, n_tok = self._local_finetune(
                program, ci, program.lts[ci], program.opts[ci], rnd)
            program.lts[ci], program.opts[ci] = lt, opt
            out.append((kd_mod.client_logits(ctx.fns, ctx.base, lt,
                                             ctx.public, ctx.eval_batch),
                        n_tok))
        return out

    # -- KD b8 ---------------------------------------------------------- #
    def kd_distill(self, program, cis, glob, rnd):
        ctx, fed = self.ctx, self.ctx.fed
        for ci in cis:
            program.lts[ci], program.opts[ci], _ = kd_mod.distill(
                ctx.fns, ctx.base, program.lts[ci], program.opts[ci],
                ctx.public, glob, fed.kd_epochs, ctx.eval_batch,
                seed=fed.seed + 31 * rnd + ci)

    # -- Split c1-c5 (server half threads through in visit order) ------- #
    def split_train(self, program, jobs, rnd):
        """jobs: [(ci, c_init)] -> [(c_lt, n_tok, n_steps, shape)]."""
        ctx, fed = self.ctx, self.ctx.fed
        sfns = program.sfns
        out = []
        for ci, c_init in jobs:
            c_lt, c_opt = c_init, sfns["opt_init"](c_init)
            r = rng_mod.local_rng(fed, rnd, ci)
            n_tok, n_steps, shape = 0, 0, None
            for batch in epoch_batches(
                    ctx.clients_data[ci], ctx.batch_size,
                    seed=fed.seed * program.epoch_seed_mult + rnd):
                r, sub = jax.random.split(r)
                nkey = dp_mod.noise_key(fed, rnd, ci, n_steps) \
                    if fed.privacy.dp_enabled else None
                c_lt, program.s_lt, c_opt, program.s_opt, _ = \
                    sfns["split_train_step"](
                        program.base_c, program.base_s, c_lt, program.s_lt,
                        c_opt, program.s_opt, _to_jax(batch), sub, nkey)
                n_tok += batch["tokens"].size
                n_steps += 1
                shape = batch["tokens"].shape
            out.append((c_lt, n_tok, n_steps, shape))
        return out


class SpmdExecutor:
    """Ready-set stacked on a leading client axis, one jitted program
    per rank bucket (``fed_spmd``).  Split fuses only contiguous
    equal-rank runs (``rank_segments``) so the shared server half keeps
    the paper's client visit order.  With ``mesh`` set, stacked inputs
    are placed with explicit client-axis NamedShardings
    (launch/sharding.py) so the client dimension shards over the mesh's
    pod/data axes in a real run."""

    backend = "spmd"
    streaming = False

    def __init__(self, ctx: RoundContext, mesh=None):
        self.ctx = ctx
        self.mesh = mesh
        self._bucket_update = None
        self._kfns = None
        self._seg_step = None

    # -- mesh placement of the stacked client axis ---------------------- #
    def _shard(self, *trees):
        if self.mesh is None:
            return trees if len(trees) > 1 else trees[0]
        from repro.launch.sharding import shard_client_tree
        out = tuple(shard_client_tree(self.mesh, t) for t in trees)
        return out if len(out) > 1 else out[0]

    # -- FedLLM a2 ------------------------------------------------------ #
    def train(self, program, jobs, rnd):
        ctx, fed, fns = self.ctx, self.ctx.fed, self.ctx.fns
        if self._bucket_update is None:
            self._bucket_update = fed_spmd.make_bucket_update(
                ctx.model, fed, ctx.task)
        by_ci = dict(jobs)
        seeds = [fed.seed * program.epoch_seed_mult + rnd + ep
                 for ep in range(fed.local_epochs)]
        results = {}
        for rank, cis in fed_spmd.rank_buckets(ctx.ranks, list(by_ci)):
            stacked_lt = fed_spmd.stack_trees([by_ci[ci] for ci in cis])
            stacked_opt = fed_spmd.stack_for_clients(
                fns["opt_init"](by_ci[cis[0]]), len(cis))
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [ctx.clients_data[ci] for ci in cis], ctx.batch_size, seeds)
            keys = rng_mod.grid_keys(fed, rnd, cis, valid.shape[1])
            stacked_lt, stacked_opt, batches, keys = self._shard(
                stacked_lt, stacked_opt, batches, keys)
            new_lt, _, _ = self._bucket_update(ctx.base, stacked_lt,
                                               stacked_opt, batches, keys,
                                               jnp.asarray(valid))
            for k, (ci, t) in enumerate(
                    zip(cis, fed_spmd.unstack_tree(new_lt))):
                results[ci] = (t, n_tok[k])
        return [results[ci] for ci, _ in jobs]

    # -- KD b1 + b2 ----------------------------------------------------- #
    def kd_train_and_logits(self, program, cis, rnd):
        ctx, fed = self.ctx, self.ctx.fed
        if self._kfns is None:
            self._kfns = fed_spmd.make_kd_spmd_fns(ctx.model, fed, ctx.task)
        kfns, lts, opts = self._kfns, program.lts, program.opts
        seeds = [fed.seed * program.epoch_seed_mult + rnd + ep
                 for ep in range(fed.local_epochs)]
        results = {}
        for rank, bcis in fed_spmd.rank_buckets(ctx.ranks, cis):
            sl = fed_spmd.stack_trees([lts[ci] for ci in bcis])
            so = fed_spmd.stack_trees([opts[ci] for ci in bcis])
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [ctx.clients_data[ci] for ci in bcis], ctx.batch_size,
                seeds)
            keys = rng_mod.grid_keys(fed, rnd, bcis, valid.shape[1])
            sl, so, batches, keys = self._shard(sl, so, batches, keys)
            sl, so, _ = kfns["client_update"](ctx.base, sl, so, batches,
                                              keys, jnp.asarray(valid))
            logits = _batched_public_logits(kfns, ctx.base, sl, ctx.public,
                                            ctx.eval_batch)
            for k, (ci, lt, opt) in enumerate(zip(
                    bcis, fed_spmd.unstack_tree(sl),
                    fed_spmd.unstack_tree(so))):
                lts[ci], opts[ci] = lt, opt
                results[ci] = (logits[k], n_tok[k])
        return [results[ci] for ci in cis]

    # -- KD b8 ---------------------------------------------------------- #
    def kd_distill(self, program, cis, glob, rnd):
        ctx, fed = self.ctx, self.ctx.fed
        kfns, lts, opts = self._kfns, program.lts, program.opts
        for rank, bcis in fed_spmd.rank_buckets(ctx.ranks, cis):
            sl = fed_spmd.stack_trees([lts[ci] for ci in bcis])
            so = fed_spmd.stack_trees([opts[ci] for ci in bcis])
            sl, so = self._shard(sl, so)
            sl, so = _batched_distill(kfns, ctx.base, sl, so, ctx.public,
                                      glob, fed, ctx.eval_batch, rnd, bcis)
            for ci, lt, opt in zip(bcis, fed_spmd.unstack_tree(sl),
                                   fed_spmd.unstack_tree(so)):
                lts[ci], opts[ci] = lt, opt

    # -- Split segments (server carry threads segment-after-segment) ---- #
    def split_train(self, program, jobs, rnd):
        ctx, fed = self.ctx, self.ctx.fed
        if self._seg_step is None:
            self._seg_step = jax.jit(fed_spmd.make_split_spmd_segment(
                ctx.model, fed, ctx.task, sfns=program.sfns))
        by_ci = dict(jobs)
        noised = fed.privacy.noise_std > 0.0
        results = {}
        # NOTE: the client axis of a split segment is *scanned* (shared
        # server carry), so it is never mesh-sharded — only the batch
        # dims inside a step shard (see SplitProgram.spmd_round for the
        # client-sharded cc2 reduction in the launch artifact).
        for rank, cis in fed_spmd.rank_segments(ctx.ranks, list(by_ci)):
            batches, valid, n_tok = fed_spmd.stack_client_batches(
                [ctx.clients_data[ci] for ci in cis], ctx.batch_size,
                [fed.seed * program.epoch_seed_mult + rnd])
            keys = rng_mod.grid_keys(fed, rnd, cis, valid.shape[1])
            extra = (dp_mod.noise_key_grid(fed, rnd, cis,
                                           valid.shape[1]),) if noised \
                else ()
            stacked_c, program.s_lt, program.s_opt, _ = self._seg_step(
                program.base_c, program.base_s, by_ci[cis[0]],
                program.s_lt, program.s_opt, batches, keys,
                jnp.asarray(valid), *extra)
            shape = tuple(batches["tokens"].shape[-2:])
            for k, (ci, t) in enumerate(
                    zip(cis, fed_spmd.unstack_tree(stacked_c))):
                results[ci] = (t, n_tok[k], int(valid[k].sum()), shape)
        return [results[ci] for ci, _ in jobs]


def _batched_public_logits(kfns, base, stacked_lt, public, batch_size):
    """b2/b6 for every client at once — same batch order and original-
    row-order scatter as kd.client_logits, giving (C, N, D) with row i
    holding public sample i's logits."""
    outs = []
    for batch in epoch_batches(public, batch_size, seed=0,
                               drop_remainder=False):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        outs.append(kfns["batched_logits"](base, stacked_lt, jb))
    stacked = jnp.concatenate(outs, axis=1)
    perm = jnp.asarray(kd_mod._epoch_perm(len(public["tokens"]), 0))
    return jnp.zeros_like(stacked).at[:, perm].set(stacked)


def _batched_distill(kfns, base, stacked_lt, stacked_opt, public, teacher,
                     fed, batch_size, rnd, client_ids):
    """b8 for every client in a (bucket-)stack at once; per-client RNG
    streams match the sequential executor's PRNGKey(seed + 31r + ci)."""
    rngs = jnp.stack([jax.random.PRNGKey(fed.seed + 31 * rnd + ci)
                      for ci in client_ids])
    n = len(public["tokens"])
    for ep in range(fed.kd_epochs):
        perm = kd_mod._epoch_perm(n, ep)
        start = 0
        for batch in epoch_batches(public, batch_size, seed=ep,
                                   drop_remainder=False):
            sel = perm[start:start + len(batch["tokens"])]
            start += len(batch["tokens"])
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            t = jnp.asarray(teacher[sel])
            rngs, subs = fed_spmd.split_each(rngs)
            stacked_lt, stacked_opt, _ = kfns["batched_kd_step"](
                base, stacked_lt, stacked_opt, jb, t, subs)
    return stacked_lt, stacked_opt


class CohortStreamingExecutor(SpmdExecutor):
    """The million-virtual-client executor (``backend="cohort"``): the
    per-chunk compute IS the SPMD executor's — the driver streams the
    round's ready set through it ``FedConfig.cohort_size`` clients at a
    time and folds partial aggregates between chunks with the jitted
    donated-buffer folds below, so peak memory is one cohort.  jit
    caches the stacked programs per (chunk size, rank, n_steps)
    signature, so every full-size chunk reuses one compile."""

    backend = "cohort"
    streaming = True


# -- streaming partial-aggregate folds -------------------------------------- #
# One jitted fold, accumulator donated: the python loop over cohorts
# re-uses the accumulator's buffers instead of materializing a new tree
# per chunk (the "donated-buffer python loop" variant of lax.scan-ing
# the cohort stream — chunk payloads live on the host, so a scan over
# them would have to materialize the full fleet first).
@functools.partial(jax.jit, donate_argnums=(0,))
def _fold_add(acc, tree, w):
    w = jnp.asarray(w, jnp.float32)
    return jax.tree.map(lambda a, x: a + w * x.astype(jnp.float32),
                        acc, tree)


def _fold_zeros(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _cohort_chunks(seq, size: int):
    """Chunk a client-id/job sequence into cohorts (<=0: one chunk)."""
    seq = list(seq)
    if size <= 0 or size >= len(seq):
        return [seq] if seq else []
    return [seq[i:i + size] for i in range(0, len(seq), size)]


def _cohort_uid(rnd: int, idx: int) -> int:
    """Unique masking-cohort id for chunk ``idx`` of round ``rnd`` —
    keys SecureAggSession cohorts and seeds their pairwise masks, so it
    only needs to be deterministic and collision-free across the run
    (chunk counts are far below the 1e6 stride)."""
    return rnd * 1_000_003 + idx


def _stream_fold_params(ctx, state, kept, global_tree):
    """Shared FedLLM/Split streaming a4/cc2 fold: one arrival chunk
    into the running staleness-weighted parameter sum.  The zeropad
    hetero path is linear per leaf, so it streams chunk-by-chunk in one
    fp32 accumulator; svd re-factorization is not, so it buffers the
    round's arrivals instead (documented O(arrivals-this-round)
    exception to the one-cohort memory bound)."""
    from repro.core.async_agg import staleness_weight
    fed = ctx.fed
    if not kept:
        return state
    # non-linear combines (svd re-factorization, robust order
    # statistics) cannot stream; buffer the round's arrivals instead
    if fed.robust_agg != "mean" or (
            fed.hetero_agg == "svd" and any(r != fed.lora_rank
                                            for r in ctx.ranks)):
        if state is None:
            state = ("buf", [])
        state[1].extend(kept)
        return state
    if state is None:
        state = ("sum", _fold_zeros(global_tree), 0.0, 0.0)
    _, acc, w_sum, raw = state
    for ci, tree, s, w in kept:
        if ctx.ranks[ci] != fed.lora_rank:
            tree = lora_lib.pad_rank(tree, fed.lora_rank)
        ws = w * staleness_weight(s, fed.staleness_decay)
        acc = _fold_add(acc, tree, ws)
        w_sum += ws
        raw += w
    return ("sum", acc, w_sum, raw)


def _finalize_param_fold(ctx, state, global_tree):
    """Close a ``_stream_fold_params`` round: anchor the absent data
    mass on the current global (the same convex combination
    ``stale_weighted_avg`` forms) and normalize.  Returns the new
    global tree — ``global_tree`` untouched when nothing was kept."""
    if state is None:
        return global_tree
    if state[0] == "buf":
        from repro.core.async_agg import combine_arrivals
        return combine_arrivals(global_tree, state[1], ctx.total_w,
                                ctx.fed, ctx.ranks)
    _, acc, w_sum, raw = state
    absent = ctx.total_w - raw
    if absent > 0:
        acc = _fold_add(acc, global_tree, absent)
        w_sum += absent
    return jax.tree.map(
        lambda a, g: (a / np.float32(w_sum)).astype(g.dtype),
        acc, global_tree)


class _LazyClientState:
    """List-like per-client state materialized on first touch.  The
    eager engines touch every index up front, reproducing the old
    list-of-all-clients bit-for-bit; under cohort streaming over a lazy
    population only participants ever materialize (KD is inherently
    per-client-stateful — a touched client's adapter IS retained after
    its cohort, the documented exception to statelessness)."""

    def __init__(self, n: int, factory):
        self._n = int(n)
        self._factory = factory
        self._vals: Dict[int, object] = {}

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, ci):
        if ci not in self._vals:
            self._vals[ci] = self._factory(ci)
        return self._vals[ci]

    def __setitem__(self, ci, val):
        self._vals[ci] = val


EXECUTORS = {"sequential": SequentialExecutor, "spmd": SpmdExecutor,
             "cohort": CohortStreamingExecutor}


# --------------------------------------------------------------------------- #
# Framework stage-specs
# --------------------------------------------------------------------------- #
class FedLLMProgram:
    """FedLLMs (paper SSII.A): a1 broadcast global LoRA params, a2 local
    PEFT fine-tuning, a3 upload the tuned params, a4 FedAvg."""

    name = "fedllm"
    epoch_seed_mult = 997

    def __init__(self, ctx: RoundContext):
        key = jax.random.PRNGKey(ctx.fed.seed + 1)
        self.global_lt = lora_lib.init_lora(key, ctx.base, ctx.targets,
                                            ctx.fed.lora_rank,
                                            ctx.fed.lora_alpha)

    def broadcast(self, ctx, cohort, rnd):
        jobs = []
        for ci in cohort:
            lt = lora_lib.maybe_truncate_rank(self.global_lt, ctx.ranks[ci],
                                              ctx.fed.lora_rank)
            ctx.ledger.record(rnd, ci, "lora_params", M.DOWN,
                              M.tree_bytes(lt))
            jobs.append((ci, lt))
        return jobs

    def local_update(self, ctx, ex, jobs, rnd):
        outs = ex.train(self, jobs, rnd)
        for (ci, _), (new_lt, n_tok) in zip(jobs, outs):
            ctx.cost[ci].add_train(ctx.cfg, n_tok,
                                   lora_lib.n_params(new_lt))
        return [(ci, new_lt)
                for (ci, _), (new_lt, _) in zip(jobs, outs)]

    def upload(self, ctx, outs, rnd):
        payloads = []
        for ci, lt in outs:
            lt = dp_mod.privatize_tree(lt, dp_mod.noise_key(ctx.fed, rnd,
                                                            ci),
                                       ctx.fed.privacy.noise_std)
            ctx.secagg.collect(ctx.secagg_start(rnd, ci), ci, lt)
            ctx.releases[ci] += 1
            payloads.append((ci, lt))
        return payloads

    def record_arrival(self, ctx, job, rnd):
        ctx.ledger.record(rnd, job.client, "lora_params", M.UP,
                          M.tree_bytes(job.payload))
        if ctx.fed.privacy.dp_enabled:
            ctx.ledger.record(rnd, job.client, "dp_meta", M.UP,
                              M.DP_META_BYTES)

    def payload_bytes(self, ctx, payload) -> int:
        return M.tree_bytes(payload)

    def payload_arrays(self, payload):
        return jax.tree.leaves(payload)

    def aggregate(self, ctx, ex, kept, arrived, rnd):
        from repro.core.async_agg import combine_arrivals
        if kept:
            self.global_lt = combine_arrivals(self.global_lt, kept,
                                              ctx.total_w, ctx.fed,
                                              ctx.ranks)

    # -- streaming a4 (cohort executor): fold chunks, finalize once --- #
    def agg_init(self, ctx):
        return None

    def agg_fold(self, ctx, ex, state, kept, rnd):
        return _stream_fold_params(ctx, state, kept, self.global_lt)

    def agg_finalize(self, ctx, ex, state, arrived, rnd):
        self.global_lt = _finalize_param_fold(ctx, state, self.global_lt)

    def edge_payload_bytes(self, ctx) -> int:
        return M.tree_bytes(self.global_lt)

    def evaluate(self, ctx):
        return evaluate(ctx.fns, ctx.base, self.global_lt, ctx.test,
                        ctx.eval_batch)

    def final_state(self, ctx):
        return self.global_lt

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def state_dict(self, ctx):
        return {"global_lt": self.global_lt}

    def load_state_dict(self, ctx, st):
        self.global_lt = st["global_lt"]

    @staticmethod
    def spmd_round(model, fed: FedConfig, task: str = "classification",
                   n_edges: int = 1):
        """The jittable whole-round program for the launch layer: the
        vmapped local scans plus the client-axis FedAvg all-reduce —
        the two-hop per-edge partial sum + cross-edge tree reduce
        (``fed_spmd.hierarchical_client_mean``) when ``n_edges > 1``."""
        return fed_spmd.make_spmd_round(model, fed, task, n_edges=n_edges)


class KDProgram:
    """KD-FedLLMs (paper SSII.B): params never cross the wire — clients
    upload public-set logits (b3), the server fuses knowledge (b4),
    distills (b5), and re-broadcasts global knowledge (b6-b8)."""

    name = "kd"
    epoch_seed_mult = 991

    def __init__(self, ctx: RoundContext):
        fed = ctx.fed
        key = jax.random.PRNGKey(fed.seed + 2)
        # per-client adapters/optimizers materialize on first
        # participation — the same fold_in(key, ci) init as the old
        # eager lists (bit-identical values), but a million-virtual-
        # client run only ever allocates the clients that train
        self.lts = _LazyClientState(
            ctx.n_clients,
            lambda ci: lora_lib.init_lora(jax.random.fold_in(key, ci),
                                          ctx.base, ctx.targets,
                                          ctx.ranks[ci], fed.lora_alpha))
        self.opts = _LazyClientState(
            ctx.n_clients, lambda ci: ctx.fns["opt_init"](self.lts[ci]))
        self.server_lt = lora_lib.init_lora(jax.random.fold_in(key, 999),
                                            ctx.base, ctx.targets,
                                            fed.lora_rank, fed.lora_alpha)
        self.server_opt = ctx.fns["opt_init"](self.server_lt)
        self.n_lora = _LazyClientState(
            ctx.n_clients, lambda ci: lora_lib.n_params(self.lts[ci]))
        self.glob = None            # latest global knowledge (b6)
        self.pub_tok = ctx.public["tokens"].size

    def broadcast(self, ctx, cohort, rnd):
        return list(cohort)         # no param download in KD

    def local_update(self, ctx, ex, jobs, rnd):
        outs = ex.kd_train_and_logits(self, jobs, rnd)
        for ci, (_, n_tok) in zip(jobs, outs):
            ctx.cost[ci].add_train(ctx.cfg, n_tok, self.n_lora[ci])
            ctx.cost[ci].add_fwd(ctx.cfg, self.pub_tok)
        return [(ci, logits) for ci, (logits, _) in zip(jobs, outs)]

    def upload(self, ctx, outs, rnd):
        payloads = []
        for ci, logits in outs:
            logits = dp_mod.privatize_logits(
                logits, dp_mod.noise_key(ctx.fed, rnd, ci), ctx.fed)
            lg, wire = kd_mod.compress_for_wire(logits, ctx.fed)
            ctx.secagg.collect(ctx.secagg_start(rnd, ci), ci, lg)
            ctx.releases[ci] += 1
            payloads.append((ci, (lg, wire)))
        return payloads

    def record_arrival(self, ctx, job, rnd):
        ctx.ledger.record(rnd, job.client, "logits", M.UP, job.payload[1])
        if ctx.fed.privacy.dp_enabled:
            ctx.ledger.record(rnd, job.client, "dp_meta", M.UP,
                              M.DP_META_BYTES)

    def payload_bytes(self, ctx, payload) -> int:
        return payload[1]

    def payload_arrays(self, payload):
        return [payload[0]]

    def aggregate(self, ctx, ex, kept, arrived, rnd):
        from repro.core.async_agg import staleness_weight
        fed = ctx.fed
        if kept:
            ws = [w * staleness_weight(s, fed.staleness_decay)
                  for _, _, s, w in kept]
            if fed.robust_agg != "mean":
                # b4 under a robust combine: order statistics over the
                # stacked client logits instead of the weighted mean
                teacher = fed_spmd.robust_client_combine(
                    jnp.stack([jnp.asarray(p[0], jnp.float32)
                               for _, p, _, _ in kept]),
                    jnp.asarray(ws, jnp.float32), fed.robust_agg,
                    fed.trim_frac, fed.clip_norm)
            else:
                teacher = kd_mod.aggregate_knowledge(
                    [p[0] for _, p, _, _ in kept], ws)
            self.server_lt, self.server_opt, _ = kd_mod.distill(
                ctx.fns, ctx.base, self.server_lt, self.server_opt,
                ctx.public, teacher, fed.kd_epochs, ctx.eval_batch,
                seed=fed.seed + rnd)
            self.glob = kd_mod.client_logits(ctx.fns, ctx.base,
                                             self.server_lt, ctx.public,
                                             ctx.eval_batch)
        # b6-b8: delivering clients re-sync against the latest knowledge
        if arrived and self.glob is not None:
            glob_wire = kd_mod.logit_wire_bytes(self.glob.shape, fed)
            cis = [j.client for j in arrived]
            for ci in cis:
                ctx.ledger.record(rnd, ci, "logits", M.DOWN, glob_wire)
                ctx.cost[ci].add_train(ctx.cfg, self.pub_tok * fed.kd_epochs,
                                       self.n_lora[ci])
            ex.kd_distill(self, cis, self.glob, rnd)

    # -- streaming b4-b8 (cohort executor) ---------------------------- #
    def agg_init(self, ctx):
        return None

    def agg_fold(self, ctx, ex, state, kept, rnd):
        """Fold one arrival chunk's logits into the running b4 teacher
        sum (the weighted mean is linear, so it streams exactly).  A
        robust combine is not linear, so it buffers the round's
        arrivals instead — the same documented O(arrivals-this-round)
        exception the svd harmonizer makes."""
        from repro.core.async_agg import staleness_weight
        if not kept:
            return state
        if ctx.fed.robust_agg != "mean":
            if state is None:
                state = ["buf", []]
            state[1].extend(kept)
            return state
        if state is None:
            state = [None, 0.0]
        acc, w_sum = state
        for ci, p, s, w in kept:
            lg = jnp.asarray(p[0])
            ws = w * staleness_weight(s, ctx.fed.staleness_decay)
            acc = _fold_add(acc if acc is not None else _fold_zeros(lg),
                            lg, ws)
            w_sum += ws
        return [acc, w_sum]

    def agg_finalize(self, ctx, ex, state, arrived, rnd):
        """b5 server distill from the normalized teacher, then the
        b6-b8 re-sync streamed over the arrived clients in cohort-sized
        chunks (one stacked distill program per chunk)."""
        from repro.core.async_agg import staleness_weight
        fed = ctx.fed
        if state is not None and isinstance(state[0], str):   # robust buffer
            ws = [w * staleness_weight(s, fed.staleness_decay)
                  for _, _, s, w in state[1]]
            state = [fed_spmd.robust_client_combine(
                jnp.stack([jnp.asarray(p[0], jnp.float32)
                           for _, p, _, _ in state[1]]),
                jnp.asarray(ws, jnp.float32), fed.robust_agg,
                fed.trim_frac, fed.clip_norm), 1.0]
        if state is not None and state[1] > 0:
            teacher = (state[0] / np.float32(state[1])).astype(jnp.float32)
            self.server_lt, self.server_opt, _ = kd_mod.distill(
                ctx.fns, ctx.base, self.server_lt, self.server_opt,
                ctx.public, teacher, fed.kd_epochs, ctx.eval_batch,
                seed=fed.seed + rnd)
            self.glob = kd_mod.client_logits(ctx.fns, ctx.base,
                                             self.server_lt, ctx.public,
                                             ctx.eval_batch)
        if arrived and self.glob is not None:
            glob_wire = kd_mod.logit_wire_bytes(self.glob.shape, fed)
            for chunk in _cohort_chunks(arrived, fed.cohort_size):
                for ci in chunk:
                    ctx.ledger.record(rnd, ci, "logits", M.DOWN, glob_wire)
                    ctx.cost[ci].add_train(ctx.cfg,
                                           self.pub_tok * fed.kd_epochs,
                                           self.n_lora[ci])
                ex.kd_distill(self, chunk, self.glob, rnd)

    def edge_payload_bytes(self, ctx) -> int:
        if self.glob is None:
            return 0
        return kd_mod.logit_wire_bytes(self.glob.shape, ctx.fed)

    def evaluate(self, ctx):
        return evaluate(ctx.fns, ctx.base, self.server_lt, ctx.test,
                        ctx.eval_batch)

    def final_state(self, ctx):
        return self.server_lt

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def state_dict(self, ctx):
        """Only the *materialized* client adapters are snapshotted —
        untouched clients re-materialize from the fold_in(key, ci)
        factory bit-identically on resume."""
        return {"lts": dict(self.lts._vals), "opts": dict(self.opts._vals),
                "server_lt": self.server_lt, "server_opt": self.server_opt,
                "glob": self.glob}

    def load_state_dict(self, ctx, st):
        self.lts._vals = dict(st["lts"])
        self.opts._vals = dict(st["opts"])
        self.server_lt = st["server_lt"]
        self.server_opt = st["server_opt"]
        self.glob = st["glob"]

    @staticmethod
    def spmd_round(model, fed: FedConfig, task: str = "classification"):
        """The jittable whole-round program for the launch layer:
        vmapped b1 local update, batched b2 public logits (with the b3
        privacy mechanism when configured), b4 client-axis knowledge
        reduction, b5 server distillation, b6 global logits and vmapped
        b8 client distillation — one program."""
        fns = make_fns(model, fed, task)
        local_update = fed_spmd.make_local_update(model, fed, task)
        noised = fed.privacy.noise_std > 0.0

        def kd_round_core(base, slt, sopt, server_lt, server_opt, batches,
                          keys, valid, weights, public_batch, client_keys,
                          server_key, noise_keys=None):
            slt, sopt, _ = jax.vmap(
                local_update, in_axes=(None, 0, 0, 0, 0, 0))(
                    base, slt, sopt, batches, keys, valid)
            logits = jax.vmap(fns["logits_fn"], in_axes=(None, 0, None))(
                base, slt, public_batch)                   # (C, Bp, D)
            if fed.privacy.dp_enabled:
                # b3 mechanism: per-client row-clipped noisy knowledge
                if noised:
                    logits = jax.vmap(
                        lambda lg, k: dp_mod.privatize_rows(lg, k, fed))(
                            logits, noise_keys)
                else:
                    logits = dp_mod.privatize_rows(logits, None, fed)
            if fed.robust_agg != "mean":
                teacher = fed_spmd.robust_client_combine(
                    logits.astype(jnp.float32), weights, fed.robust_agg,
                    fed.trim_frac, fed.clip_norm)
            else:
                teacher = kd_mod.aggregate_knowledge_batched(logits,
                                                             weights)
            server_lt, server_opt, _ = fns["kd_step"](
                base, server_lt, server_opt, public_batch, teacher,
                server_key)
            glob = fns["logits_fn"](base, server_lt, public_batch)
            slt, sopt, _ = jax.vmap(
                fns["kd_step"], in_axes=(None, 0, 0, None, None, 0))(
                    base, slt, sopt, public_batch, glob, client_keys)
            return slt, sopt, server_lt, server_opt

        return kd_round_core


class SplitProgram:
    """Split-FedLLMs (paper SSII.C): c1-c5 split training (activations
    up, gradients down, server half in the loop) plus the cc1-cc4
    FedAvg of the *client-side* adapters."""

    name = "split"
    epoch_seed_mult = 983

    def __init__(self, ctx: RoundContext):
        fed, cfg = ctx.fed, ctx.cfg
        self.sfns = split_mod.make_split_fns(ctx.model, fed, ctx.task)
        L = self.sfns["n_client_groups"]
        key = jax.random.PRNGKey(fed.seed + 3)
        full_lt = lora_lib.init_lora(key, ctx.base, ctx.targets,
                                     fed.lora_rank, fed.lora_alpha)
        self.c_global, self.s_lt = split_mod.split_lora(full_lt, L)
        self.base_c, self.base_s = split_mod.split_base(
            ctx.base, L, cfg.is_encoder_decoder)
        self.s_opt = self.sfns["opt_init"](self.s_lt)
        self.frac_client = L / max(self.sfns["n_groups"], 1)
        self.label_bytes = ctx.batch_size * 4 \
            if "labels" in ctx.clients_data[0] else 0
        self.joined = full_lt

    def broadcast(self, ctx, cohort, rnd):
        jobs = []
        for ci in cohort:
            c_init = lora_lib.maybe_truncate_rank(
                self.c_global, ctx.ranks[ci], ctx.fed.lora_rank)
            ctx.ledger.record(rnd, ci, "lora_params", M.DOWN,
                              M.tree_bytes(c_init))                    # cc3
            jobs.append((ci, c_init))
        return jobs

    def local_update(self, ctx, ex, jobs, rnd):
        outs = ex.split_train(self, jobs, rnd)
        priv = ctx.fed.privacy
        res = []
        for (ci, _), (c_lt, n_tok, n_steps, shape) in zip(jobs, outs):
            if n_steps:          # a sub-batch-size client trains 0 steps
                up, down = self.sfns["wire_bytes_per_batch"](shape)
                for _ in range(n_steps):
                    ctx.ledger.record(rnd, ci, "activations", M.UP,
                                      up + self.label_bytes)           # c2
                    ctx.ledger.record(rnd, ci, "act_grads", M.DOWN,
                                      down)                            # c4
                    if priv.dp_enabled:
                        ctx.ledger.record(rnd, ci, "dp_meta", M.UP,
                                          M.DP_META_BYTES)
            ctx.releases[ci] += n_steps     # per-client c2 noise events
            ctx.cost[ci].add_train(ctx.cfg, n_tok,
                                   lora_lib.n_params(c_lt),
                                   frac_layers=self.frac_client)
            res.append((ci, c_lt))
        return res

    def upload(self, ctx, outs, rnd):
        # the c2 activation noise is Split's DP mechanism (inside the
        # step); the cc1 adapter upload is masked but not noised
        for ci, c_lt in outs:
            ctx.secagg.collect(ctx.secagg_start(rnd, ci), ci, c_lt)
        return outs

    def record_arrival(self, ctx, job, rnd):
        ctx.ledger.record(rnd, job.client, "lora_params", M.UP,
                          M.tree_bytes(job.payload))                   # cc1

    def payload_bytes(self, ctx, payload) -> int:
        return M.tree_bytes(payload)

    def payload_arrays(self, payload):
        return jax.tree.leaves(payload)

    def aggregate(self, ctx, ex, kept, arrived, rnd):
        from repro.core.async_agg import combine_arrivals
        if kept:                                                       # cc2
            self.c_global = combine_arrivals(self.c_global, kept,
                                             ctx.total_w, ctx.fed,
                                             ctx.ranks)
        self.joined = split_mod.join_lora(self.c_global, self.s_lt)

    # -- streaming cc2 (cohort executor) ------------------------------ #
    def agg_init(self, ctx):
        return None

    def agg_fold(self, ctx, ex, state, kept, rnd):
        return _stream_fold_params(ctx, state, kept, self.c_global)

    def agg_finalize(self, ctx, ex, state, arrived, rnd):
        self.c_global = _finalize_param_fold(ctx, state, self.c_global)
        self.joined = split_mod.join_lora(self.c_global, self.s_lt)

    def edge_payload_bytes(self, ctx) -> int:
        return M.tree_bytes(self.c_global)

    def evaluate(self, ctx):
        return evaluate(ctx.fns, ctx.base, self.joined, ctx.test,
                        ctx.eval_batch)

    def final_state(self, ctx):
        return self.joined

    # -- checkpoint/resume (checkpoint/federated.py) --------------------- #
    def state_dict(self, ctx):
        return {"c_global": self.c_global, "s_lt": self.s_lt,
                "s_opt": self.s_opt}

    def load_state_dict(self, ctx, st):
        self.c_global, self.s_lt = st["c_global"], st["s_lt"]
        self.s_opt = st["s_opt"]
        self.joined = split_mod.join_lora(self.c_global, self.s_lt)

    @staticmethod
    def spmd_round(model, fed: FedConfig, task: str = "generative",
                   sfns=None, client_sharding=None):
        """The jittable whole-round program for the launch layer;
        ``client_sharding(ndim) -> NamedSharding`` pins the stacked
        client-half axis to the mesh's client axes before the closing
        cc2 reduction."""
        return fed_spmd.make_split_spmd_round(
            model, fed, task, sfns=sfns, client_sharding=client_sharding)


PROGRAMS = {"fedllm": FedLLMProgram, "kd": KDProgram,
            "split": SplitProgram}


# --------------------------------------------------------------------------- #
# The driver: one loop for every engine combination
# --------------------------------------------------------------------------- #
def run_program(model, base, cfg: ModelConfig, fed: FedConfig, targets,
                public: Dict, clients_data: List[Dict], test: Dict,
                task: str, batch_size: int, eval_batch: int,
                verbose: bool, backend: str = "sequential",
                mesh=None, checkpoint_every: int = 0,
                checkpoint_dir: str = None,
                resume_from: str = None) -> FedResult:
    """Run ``fed.rounds`` federated rounds of ``fed.framework`` through
    the composed pipeline.  ``backend`` selects the executor; ``mesh``
    (optional) makes the SPMD executor shard the stacked client axis
    over the mesh's client axes.

    Fault tolerance (src/repro/faults/): when ``fed.faults`` is active
    a seeded FaultPlan drops, delays, or corrupts uploads at the
    injection seam between local_update and upload; every arrival then
    passes the validation middleware (finite check + optional norm
    screen), offenders are quarantined (ledger ``quarantine`` events,
    secure-agg discard -> the cohort's survivors run the normal mask
    recovery), and a round whose surviving arrivals fall below
    ``fed.quorum`` x |starters| rolls over deterministically with the
    global state unchanged.

    Crash recovery: ``checkpoint_every > 0`` snapshots the complete run
    state (program params/optimizers, in-flight payloads, schedule RNG,
    secure-agg session, ledger, history, release counters) into
    ``checkpoint_dir`` after every k-th round via
    checkpoint/federated.py; ``resume_from`` restores the latest
    snapshot in a directory and continues — bit-exactly equal to the
    uninterrupted run (ledger bytes, metrics, final params)."""
    ctx = RoundContext(model, base, cfg, fed, targets, public,
                       clients_data, test, task, batch_size, eval_batch,
                       verbose)
    program = PROGRAMS[fed.framework](ctx)
    ex = EXECUTORS[backend](ctx, mesh)
    schedule = make_schedule(fed, ctx.n_clients)
    streaming = getattr(ex, "streaming", False)
    if streaming:
        from repro.launch import mesh as mesh_lib
        n_edges = fed.n_edges or mesh_lib.n_edges(mesh)
    else:
        n_edges = 1
    hierarchical = streaming and n_edges > 1
    if hierarchical:
        # two-hop topology: every per-client wire event is the first
        # hop now (client -> its edge aggregator); the edge -> server
        # hop is charged per live edge after each aggregation below
        ctx.ledger.default_hop = M.CLIENT_EDGE
    tag = f"{fed.framework}/{backend}" + \
        ("/async" if fed.aggregation == "async" else "")

    # -- fault-tolerance middleware ------------------------------------- #
    plan = None
    if fed.faults.enabled:
        from repro.faults import FaultPlan
        plan = FaultPlan(fed, ctx.n_clients)

    def _submit(outs, rnd):
        """The upload seam: Byzantine corruption happens BEFORE the
        upload stage (so privacy noise / compression / secure-agg
        masking all apply to what the corrupt client actually sends),
        dropout loses the payload after it (the bytes were spent —
        charged as ``retransmit``), stragglers submit with extra lag."""
        if plan is not None:
            outs = [(ci, plan.corrupt(p, rnd, ci)) for ci, p in outs]
        for ci, payload in program.upload(ctx, outs, rnd):
            if plan is not None and plan.dropped(rnd, ci):
                ctx.ledger.record(rnd, ci, "retransmit", M.UP,
                                  program.payload_bytes(ctx, payload))
                ctx.secagg.discard(ctx.secagg_start(rnd, ci), ci)
                continue
            extra = plan.extra_delay(rnd, ci) if plan is not None else 0
            schedule.submit(rnd, ci, payload, extra)

    def _screen(arrivals):
        """Validation verdicts for the whole round's arrivals at once
        (norm screen medians are round-global, so flat and streaming
        drivers quarantine the identical set)."""
        if not arrivals:
            return []
        from repro.faults import guard as fault_guard
        return fault_guard.screen(
            [program.payload_arrays(j.payload) for j in arrivals],
            fed.screen_factor)

    def _quarantine(j, rnd):
        ctx.ledger.record(rnd, j.client, "quarantine", M.UP,
                          program.payload_bytes(ctx, j.payload))
        ctx.secagg.discard(ctx.secagg_start(j.start, j.client), j.client)

    # -- crash recovery -------------------------------------------------- #
    mgr = None
    if checkpoint_every and checkpoint_every > 0:
        if not checkpoint_dir:
            raise ValueError("checkpoint_every requires checkpoint_dir")
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(checkpoint_dir)
    start_rnd, rollovers = 0, 0
    if resume_from:
        from repro.checkpoint import federated as fed_ckpt
        start_rnd, rollovers = fed_ckpt.restore_run(resume_from, ctx,
                                                    program, schedule)

    for rnd in range(start_rnd, fed.rounds):
        # start cohort: free clients pull state and form this round's
        # secure-agg masking cohort (payloads are created — and masked —
        # now, even when they deliver rounds later)
        starters = schedule.starters(rnd)
        if streaming:
            # the ready set streams through the stacked programs one
            # cohort-sized chunk at a time; each chunk is its own
            # secure-agg masking cohort so its payloads can be freed
            # the moment the whole chunk delivers
            for k, chunk in enumerate(
                    _cohort_chunks(starters, fed.cohort_size)):
                cid = _cohort_uid(rnd, k)
                for ci in chunk:
                    ctx._cohort_ids[(rnd, ci)] = cid
                ctx.secagg.begin_cohort(ctx.ledger, rnd, chunk,
                                        cohort_id=cid)
                jobs = program.broadcast(ctx, chunk, rnd)
                outs = program.local_update(ctx, ex, jobs, rnd)
                _submit(outs, rnd)
        else:
            ctx.secagg.begin_cohort(ctx.ledger, rnd, starters)
            jobs = program.broadcast(ctx, starters, rnd)
            outs = program.local_update(ctx, ex, jobs, rnd)
            _submit(outs, rnd)
        # arrivals: record wire traffic, drop too-stale updates (their
        # pairwise masks recovered like any absent cohort member's)
        if streaming:
            # group arrivals by masking cohort (insertion order), fold
            # each group into the running partial aggregate and free its
            # secagg payloads before touching the next — peak memory is
            # one cohort of payloads plus one fp32 accumulator
            arrivals = schedule.pop_arrivals(rnd)
            ok = _screen(arrivals)
            n_kept = sum(1 for j, good in zip(arrivals, ok)
                         if good and rnd - j.start <= fed.max_staleness)
            roll = bool(fed.quorum > 0 and starters
                        and n_kept < fed.quorum * len(starters))
            groups: Dict[int, List] = {}
            for j, good in zip(arrivals, ok):
                groups.setdefault(ctx.secagg_start(j.start, j.client),
                                  []).append((j, good))
            state = program.agg_init(ctx)
            arrived_cis, used_edges = [], set()
            for gi, (gkey, gjobs) in enumerate(groups.items()):
                kept_chunk, delivered = [], []
                for j, good in gjobs:
                    if not good:
                        _quarantine(j, rnd)
                        continue
                    arrived_cis.append(j.client)
                    program.record_arrival(ctx, j, rnd)
                    s = rnd - j.start
                    if s <= fed.max_staleness:
                        kept_chunk.append((j.client, j.payload, s,
                                           ctx.data_w[j.client]))
                        delivered.append((gkey, j.client))
                    else:
                        ctx.secagg.discard(gkey, j.client)
                ctx.secagg.deliver(ctx.ledger, rnd, delivered)
                if not roll:
                    state = program.agg_fold(ctx, ex, state, kept_chunk,
                                             rnd)
                used_edges.add(gi % n_edges)
            if roll:
                # below quorum: the cohort's payloads were received and
                # their secure-agg masks settled, but the round rolls
                # over — nothing folds into the global state
                rollovers += 1
                state, arrived_cis = None, []
            program.agg_finalize(ctx, ex, state, arrived_cis, rnd)
            if hierarchical and arrived_cis:
                # second hop: each edge that aggregated a cohort this
                # round forwards one fused payload up and pulls the new
                # global down (negative ids denote edge aggregators)
                eb = program.edge_payload_bytes(ctx)
                for e in sorted(used_edges):
                    ctx.ledger.record(rnd, -(e + 1), "edge_agg", M.UP,
                                      eb, hop=M.EDGE_SERVER)
                    ctx.ledger.record(rnd, -(e + 1), "edge_agg", M.DOWN,
                                      eb, hop=M.EDGE_SERVER)
            arrived_n = len(arrived_cis)
        else:
            arrivals = schedule.pop_arrivals(rnd)
            ok = _screen(arrivals)
            kept, delivered, arrived = [], [], []
            for j, good in zip(arrivals, ok):
                if not good:
                    _quarantine(j, rnd)
                    continue
                arrived.append(j)
                program.record_arrival(ctx, j, rnd)
                s = rnd - j.start
                if s <= fed.max_staleness:
                    kept.append((j.client, j.payload, s,
                                 ctx.data_w[j.client]))
                    delivered.append((j.start, j.client))
                else:
                    ctx.secagg.discard(j.start, j.client)
            ctx.secagg.deliver(ctx.ledger, rnd, delivered)
            if fed.quorum > 0 and starters \
                    and len(kept) < fed.quorum * len(starters):
                rollovers += 1
                kept, arrived = [], []
            program.aggregate(ctx, ex, kept, arrived, rnd)
            arrived_n = len(arrived)
        acc, loss = program.evaluate(ctx)
        ctx.history.append(M.RoundMetrics(
            rnd, acc, loss, ctx.ledger.mean_client_bytes_per_round(),
            float(np.mean([c.flops for c in ctx.cost])) if ctx.cost else 0.0,
            epsilon=round_epsilon(ctx.acct, max(ctx.releases, default=0))))
        if verbose:
            print(f"[{tag}] round {rnd}: acc={acc:.4f} loss={loss:.4f}"
                  + (f" arrived={arrived_n}"
                     if fed.aggregation == "async" else ""))
        if mgr is not None and (rnd + 1) % checkpoint_every == 0:
            from repro.checkpoint import federated as fed_ckpt
            fed_ckpt.save_run(mgr, ctx, program, schedule, rnd, rollovers)
    return FedResult(ctx.history, ctx.ledger, program.final_state(ctx),
                     [c.flops for c in ctx.cost], rollovers=rollovers)
