"""Seeded fault-injection plan for the round engine.

Every fault decision is a pure function of ``(FedConfig.seed,
FaultConfig.seed, round, client)`` via the ``core/rng.host_fold_rng``
fold-in chain, domain-separated from the dropout / privacy / batching
streams by the ``_FAULT_STREAM`` tag.  That makes a faulted run exactly
reproducible across frameworks, backends, and schedules — and across a
checkpoint/resume boundary, since the plan carries no mutable state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as rng_mod

# Domain-separation tag for the fault stream (never collides with the
# dropout `seed*1013+...` roots or the privacy fold chains).
_FAULT_STREAM = 0xFA17

BYZANTINE_MODES = ("nan", "inf", "sign_flip", "norm_inflation")


class FaultPlan:
    """Deterministic per-(round, client) fault decisions.

    * ``dropped(rnd, ci)``   — the upload is lost in transit.
    * ``extra_delay(rnd, ci)`` — extra rounds the upload takes to arrive
      (feeds the ParticipationSchedule's arrival time).
    * ``corrupts(ci)``       — ci is one of the ``byzantine`` clients (a
      seeded fixed subset of the population, chosen once per plan).
    * ``corrupt(payload, rnd, ci)`` — apply the Byzantine mode to every
      float leaf of a payload pytree.
    """

    def __init__(self, fed, n_clients: int):
        fc = fed.faults
        if fc.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(
                f"unknown byzantine_mode {fc.byzantine_mode!r} "
                f"(expected one of {BYZANTINE_MODES})")
        if fc.byzantine > n_clients:
            raise ValueError(
                f"byzantine={fc.byzantine} exceeds n_clients={n_clients}")
        self.fed, self.fc, self.n_clients = fed, fc, n_clients
        if fc.byzantine > 0:
            perm = rng_mod.host_fold_rng(
                fed.seed, _FAULT_STREAM, fc.seed).permutation(n_clients)
            self.byzantine = frozenset(int(c) for c in perm[:fc.byzantine])
        else:
            self.byzantine = frozenset()

    # ------------------------------------------------------------------ #
    def _draws(self, rnd: int, ci: int) -> Tuple[float, float]:
        """(dropout_draw, straggler_draw) — a fixed draw order per
        (round, client) so toggling one fault kind never shifts the
        other's stream."""
        g = rng_mod.host_fold_rng(
            self.fed.seed, _FAULT_STREAM, self.fc.seed, rnd, ci)
        return float(g.uniform()), float(g.uniform())

    def dropped(self, rnd: int, ci: int) -> bool:
        if self.fc.dropout_rate <= 0.0:
            return False
        return self._draws(rnd, ci)[0] < self.fc.dropout_rate

    def extra_delay(self, rnd: int, ci: int) -> int:
        if self.fc.straggler_rate <= 0.0:
            return 0
        if self._draws(rnd, ci)[1] < self.fc.straggler_rate:
            return int(self.fc.straggler_delay)
        return 0

    # ------------------------------------------------------------------ #
    def corrupts(self, ci: int) -> bool:
        return ci in self.byzantine

    def corrupt(self, payload, rnd: int, ci: int):
        """Byzantine-corrupt every float leaf of ``payload`` (other
        leaves — wire-byte ints, masks — pass through untouched)."""
        if not self.corrupts(ci):
            return payload
        mode, scale = self.fc.byzantine_mode, self.fc.byzantine_scale

        def leaf(x):
            if not hasattr(x, "dtype") or not jnp.issubdtype(
                    jnp.asarray(x).dtype, jnp.floating):
                return x
            x = jnp.asarray(x)
            if mode == "nan":
                return jnp.full_like(x, jnp.nan)
            if mode == "inf":
                return jnp.full_like(x, jnp.inf)
            if mode == "sign_flip":
                return -x
            return x * jnp.asarray(scale, x.dtype)   # norm_inflation

        return jax.tree.map(leaf, payload)
