"""Fault tolerance for the federated round engine.

``plan.FaultPlan`` injects seeded dropouts, straggler delays, and
Byzantine payload corruption into any framework x backend x schedule
combo; ``guard`` holds the upload-seam validation helpers (finite
check + norm screen) the round driver quarantines offenders with.
"""
from repro.faults.plan import FaultPlan  # noqa: F401
