"""Upload-seam payload validation (finite check + norm screen).

The round driver (core/round_program.py) runs these over every arrival
before it reaches the aggregate stage: non-finite payloads are always
quarantined; when ``FedConfig.screen_factor > 0`` arrivals whose L2
norm exceeds ``screen_factor`` x the round's median arrival norm are
quarantined too.  Checks are host-side numpy over the payload's float
leaves — they never modify the payload, so a clean run's values and
ledger bytes are untouched.
"""
from __future__ import annotations

import math
from typing import List, Sequence

import jax.numpy as jnp
import numpy as np


def float_leaves(payload) -> List:
    """The float array leaves of a payload pytree (ints — wire-byte
    counts, token ids — cannot be non-finite and are skipped)."""
    import jax
    out = []
    for x in jax.tree.leaves(payload):
        if hasattr(x, "dtype") and jnp.issubdtype(
                jnp.asarray(x).dtype, jnp.floating):
            out.append(x)
    return out


def arrays_finite(arrays: Sequence) -> bool:
    for x in arrays:
        a = np.asarray(x).astype(np.float32, copy=False)
        if not np.isfinite(a).all():
            return False
    return True


def arrays_norm(arrays: Sequence) -> float:
    """Global L2 norm over all float leaves (fp32 accumulation — the
    screen threshold is coarse, exact dtype does not matter)."""
    total = 0.0
    for x in arrays:
        a = np.asarray(x).astype(np.float64, copy=False)
        total += float(np.square(a).sum())
    return math.sqrt(total)


def screen(payload_leaf_lists: Sequence[Sequence],
           screen_factor: float) -> List[bool]:
    """Verdicts (True = keep) for one round's arrivals.

    Computed over the *whole* round at once — flat and cohort-streaming
    drivers therefore quarantine the identical set, keeping ledger
    parity across backends.  The median is taken over the finite
    arrivals only, so a NaN payload cannot poison the screen itself.
    """
    ok = [arrays_finite(leaves) for leaves in payload_leaf_lists]
    if screen_factor > 0.0 and any(ok):
        norms = [arrays_norm(leaves) if good else 0.0
                 for leaves, good in zip(payload_leaf_lists, ok)]
        med = float(np.median([n for n, good in zip(norms, ok) if good]))
        if med > 0.0:
            limit = screen_factor * med
            ok = [good and n <= limit for good, n in zip(ok, norms)]
    return ok
