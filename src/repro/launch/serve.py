"""Runnable serving driver: batched autoregressive decode with the KV /
recurrent cache for any --arch (reduced by default).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.models.factory import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-tiny", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size and not args.arch.startswith("gpt2"):
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B = args.batch
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (B, args.prompt_len), 1, cfg.vocab_size,
                                jnp.int32)
    batch = {"tokens": prompt}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.encoder_seq_len,
                                         cfg.d_model))
    cache = model.init_cache(params, B, max_len, batch, dtype=jnp.float32)

    step = jax.jit(model.decode_step)
    # prefill by single-step decode (teacher forcing over the prompt)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t], jnp.asarray(t))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(args.prompt_len, max_len):
        out.append(np.asarray(tok))
        key, sub = jax.random.split(key)
        logits, cache = step(params, cache, tok, jnp.asarray(t))
        if args.temperature > 0:
            tok = jax.random.categorical(
                sub, logits / args.temperature).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"arch={cfg.name}: generated {gen.shape} tokens in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s batched)")
    print("sample:", gen[0][:16].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
