"""Runnable training driver (CPU-scale): LoRA fine-tune of any --arch
(reduced variant by default) on the synthetic Markov LM corpus.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 50 --batch 8 --seq 64 [--full-size] [--ckpt-dir /tmp/ck]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS, get_config
from repro.core.fedavg import make_fns
from repro.data import synthetic
from repro.models.factory import build_model
from repro.peft import lora as lora_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-tiny", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (big!) instead of .reduced()")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size and not args.arch.startswith("gpt2"):
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    key = jax.random.PRNGKey(args.seed)
    base = model.init(key)
    fed = FedConfig(lora_rank=args.rank, lr=args.lr, lora_dropout=0.0,
                    lora_targets=lora_lib.default_targets(cfg))
    fns = make_fns(model, fed, task="generative")
    lt = lora_lib.init_lora(jax.random.fold_in(key, 1), base,
                            fed.lora_targets, args.rank)
    opt = fns["opt_init"](lt)
    print(f"LoRA params: {lora_lib.n_params(lt)/1e3:.1f}k "
          f"(targets={fed.lora_targets})")

    corpus = synthetic.markov_corpus(200_000, cfg.vocab_size,
                                     seed=args.seed)
    batches = synthetic.lm_batches(corpus, args.batch, args.seq,
                                   seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    t0, losses = time.time(), []
    for step in range(args.steps):
        batch = next(batches)
        jb = {"tokens": jnp.asarray(batch["tokens"][:, :args.seq]),
              "lengths": jnp.full((args.batch,), args.seq, jnp.int32),
              "labels": jnp.zeros((args.batch,), jnp.int32)}
        if cfg.n_image_tokens:
            jb["img_embeds"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.n_image_tokens, cfg.image_embed_dim))
        if cfg.is_encoder_decoder:
            jb["enc_embeds"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.encoder_seq_len, cfg.d_model))
        key, sub = jax.random.split(key)
        lt, opt, loss = fns["train_step"](base, lt, opt, jb, sub)
        losses.append(float(loss))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
        if ckpt and (step + 1) % 25 == 0:
            ckpt.save(step + 1, lt, {"loss": losses[-1]})

    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
