"""input_specs(): ShapeDtypeStruct stand-ins for every model input
(charter MULTI-POD DRY-RUN step 2) — weak-type-correct, shardable, no
device allocation.  Modality frontends are stubs: VLM patch embeddings
and audio frame embeddings arrive as precomputed arrays of the right
shape."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    GB, S = shape.global_batch, shape.seq_len
    out = {}
    if cfg.n_image_tokens:
        # image prefix consumes part of the context budget (anyres tiling)
        S_text = S - cfg.n_image_tokens
        out["img_embeds"] = SDS((GB, cfg.n_image_tokens,
                                 cfg.image_embed_dim), jnp.bfloat16)
        out["tokens"] = SDS((GB, S_text), jnp.int32)
    elif cfg.is_encoder_decoder:
        out["enc_embeds"] = SDS((GB, cfg.encoder_seq_len, cfg.d_model),
                                jnp.bfloat16)
        out["tokens"] = SDS((GB, S), jnp.int32)
    else:
        out["tokens"] = SDS((GB, S), jnp.int32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """One new token against a seq_len-deep KV cache."""
    GB = shape.global_batch
    return {"token": SDS((GB,), jnp.int32), "pos": SDS((), jnp.int32)}


def abstract_cache(model, params_shape, shape: ShapeConfig,
                   dtype=jnp.bfloat16):
    """Cache ShapeDtypeStructs via eval_shape (no allocation)."""
    cfg = model.cfg
    GB = shape.global_batch

    def make(params):
        batch = None
        if cfg.is_encoder_decoder:
            batch = {"enc_embeds": jnp.zeros(
                (GB, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)}
        return model.init_cache(params, GB, shape.seq_len, batch, dtype)

    return jax.eval_shape(make, params_shape)
