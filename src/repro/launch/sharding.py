"""Sharding policy: PartitionSpec trees for params, LoRA, optimizer
state, batches and KV caches (DESIGN SS5).

Name-based rules with divisibility fallbacks, evaluated at spec-build
time against the actual mesh:

- embeddings / LM head: vocab-dim on ``model`` when divisible, else the
  d_model dim, else replicate.
- attention / MLP projections: column-parallel in, row-parallel out
  (megatron layout); non-divisible dims fall back to the other scheme,
  then to replication (qwen2's 12 heads, whisper's 51865 vocab).
- MoE experts: expert dim on ``model`` when divisible (qwen3-moe 128/16),
  else the per-expert ffn dim (mixtral 8 experts < 16 shards).
- LoRA A follows its base matrix's input sharding, B the output sharding.
- KV caches: batch on data axes, cache sequence dim on ``model``
  (sequence-sharded cache: a 32k x128-batch mistral cache drops from
  94 GiB to 5.9 GiB per device).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# column-parallel (shard output dim) / row-parallel (shard input dim)
COL = {"wq", "wk", "wv", "w_gate", "w_in", "cm_w_k", "w_rec_in",
       "w_gate_in", "w_r", "w_k", "w_v", "w_g", "cm_w_r", "w_down"}
ROW = {"wo", "w_out", "cm_w_v", "w_o", "w_up"}
VEC_COL = {"bq", "bk", "bv", "b_a", "b_x", "lambda", "conv_b"}
REPLICATE = {"router", "decay_a", "decay_b", "img_proj"}


def _div(n: int, m: int) -> bool:
    return n % m == 0


# --------------------------------------------------------------------------- #
# Stacked-client-axis shardings (round engine + fed_round dry-run)
# --------------------------------------------------------------------------- #
def client_spec(mesh, ndim: int) -> P:
    """PartitionSpec putting a leading stacked-client axis on the mesh's
    client axes (launch/mesh.client_axes) and replicating the rest."""
    from repro.launch.mesh import client_axes
    return P(client_axes(mesh), *([None] * (ndim - 1)))


def client_shardings(mesh, tree):
    """Mirror-structured NamedSharding tree for client-stacked arrays
    (leaves have the client dimension leading)."""
    return jax.tree.map(
        lambda x: NamedSharding(mesh, client_spec(mesh, x.ndim)), tree)


def shard_client_tree(mesh, tree):
    """Place a client-stacked tree with explicit client-axis
    NamedShardings; no-op when the stack size does not divide the
    client-axis extent (e.g. a small rank bucket), so callers can apply
    it unconditionally."""
    from repro.launch.mesh import client_axis_size
    leaves = jax.tree.leaves(tree)
    if not leaves or leaves[0].shape[0] % max(client_axis_size(mesh), 1):
        return tree
    return jax.device_put(tree, client_shardings(mesh, tree))


class ShardingPolicy:
    def __init__(self, mesh, cfg):
        self.mesh = mesh
        self.cfg = cfg
        self.M = mesh.shape["model"]
        self.dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= mesh.shape[a]

    # ------------------------------------------------------------------ #
    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _pad(self, spec_tail, ndim):
        return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))

    # ------------------------------------------------------------------ #
    def param_spec(self, path, leaf) -> P:
        name = path[-1]
        shape = leaf.shape
        nd = leaf.ndim
        M = self.M
        if nd == 0 or name.startswith("mu_") or name in (
                "scale", "bias", "ln_x", "bonus_u", "decay_w0"):
            return P()
        if name == "embed":
            V, d = shape[-2], shape[-1]
            if _div(V, M):
                return self._pad([("model"), None], nd)
            if _div(d, M):
                return self._pad([None, "model"], nd)
            return P()
        if name == "pos_embed":
            return P()
        if name == "lm_head":
            d, V = shape[-2], shape[-1]
            if _div(V, M):
                return self._pad([None, "model"], nd)
            if _div(d, M):
                return self._pad(["model", None], nd)
            return P()
        # MoE expert tensors: (.., E, d_in, d_out)
        is_expert = self.cfg.is_moe and name in (
            "w_gate", "w_in", "w_out") and nd >= 3 and \
            shape[-3] == self.cfg.n_experts
        if is_expert:
            E = shape[-3]
            if _div(E, M):
                return self._pad(["model", None, None], nd)
            # fall back: shard the per-expert ffn dim
            io = -1 if name in ("w_gate", "w_in") else -2
            if _div(shape[io], M):
                tail = [None, None, None]
                tail[io] = "model"
                return self._pad(tail, nd)
            return P()
        if name in REPLICATE:
            return P()
        if name == "conv_w":                       # (K, w)
            if _div(shape[-1], M):
                return self._pad([None, "model"], nd)
            return P()
        if name in ("w_a", "w_x"):                 # (w, w) lru gates
            if _div(shape[-1], M):
                return self._pad([None, "model"], nd)
            return P()
        if name in VEC_COL:
            if _div(shape[-1], M):
                return self._pad(["model"], nd)
            return P()
        if name in COL:
            if _div(shape[-1], M):
                return self._pad([None, "model"], nd)
            if _div(shape[-2], M):
                return self._pad(["model", None], nd)
            return P()
        if name in ROW:
            if _div(shape[-2], M):
                return self._pad(["model", None], nd)
            if _div(shape[-1], M):
                return self._pad([None, "model"], nd)
            return P()
        return P()

    # ------------------------------------------------------------------ #
    def lora_spec(self, base_path, which: str, leaf) -> P:
        """A follows base input dim; B follows base output dim."""
        name = base_path[-1]
        nd = leaf.ndim
        M = self.M
        col = name in COL or name in ("embed", "lm_head")
        if which == "a":
            if not col and _div(leaf.shape[-2], M):
                return self._pad(["model", None], nd)    # row-parallel base
            return P()
        if col and _div(leaf.shape[-1], M):
            return self._pad([None, "model"], nd)
        return P()

    # ------------------------------------------------------------------ #
    def tree_specs(self, params) -> object:
        """Mirror-structured PartitionSpec tree (params or bound trees)."""

        def rec(t, path):
            if isinstance(t, dict):
                if set(t) == {"a", "b"} and hasattr(t["a"], "ndim"):
                    return {"a": self.lora_spec(path, "a", t["a"]),
                            "b": self.lora_spec(path, "b", t["b"])}
                return {k: rec(v, path + (k,)) for k, v in t.items()}
            if isinstance(t, (tuple, list)):
                return tuple(rec(v, path) for v in t)
            if t is None:
                return None
            return self.param_spec(path, t)

        return rec(params, ())

    def tree_shardings(self, params):
        return jax.tree.map(
            lambda s: self.named(s),
            self.tree_specs(params),
            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------ #
    def opt_specs(self, lora_specs):
        """Adam state mirrors its params; step scalar replicated."""
        return {"m": lora_specs, "v": lora_specs, "step": P()}

    # ------------------------------------------------------------------ #
    def batch_spec(self, batch_shapes, shardable_batch: bool = True) -> dict:
        dp = self.dp if shardable_batch else ()
        out = {}
        for k, v in batch_shapes.items():
            lead = dp if (shardable_batch
                          and _div(v.shape[0], max(self.dp_size, 1))) else ()
            out[k] = P(lead, *([None] * (v.ndim - 1))) if lead else P(
                *([None] * v.ndim))
        return out

    # ------------------------------------------------------------------ #
    def cache_spec(self, path, leaf) -> P:
        """KV caches: batch on data axes, cache seq dim on model."""
        name = path[-1]
        nd = leaf.ndim
        shape = leaf.shape
        # attention kv caches: (..., B, S_cache, KV, hd).  Sequence-shard
        # only LARGE caches: ring buffers (sliding windows <= 4k) are small
        # and a model-sharded seq dim makes every decode update/read
        # all-gather the full cache (SSPerf hillclimb 2: mixtral decode
        # dropped 470 MB -> ~0 all-gather per layer).
        if name in ("k", "v") and nd >= 4:
            spec = [None] * nd
            if _div(shape[-4], self.dp_size):
                spec[-4] = self.dp
            if shape[-3] >= 16384 and _div(shape[-3], self.M):
                spec[-3] = "model"
            return P(*spec)
        # recurrent states: (..., B, ...) — batch after optional group dim
        b_ax = nd - 2 if name in ("h", "x_tm", "x_cm") else None
        spec = [None] * nd
        for ax in range(nd):
            if leaf.shape[ax] >= self.dp_size and _div(
                    leaf.shape[ax], self.dp_size):
                spec[ax] = self.dp
                break
        return P(*spec)

    def cache_shardings(self, cache_shapes):
        def rec(t, path):
            if isinstance(t, dict):
                return {k: rec(v, path + (k,)) for k, v in t.items()}
            if isinstance(t, (tuple, list)):
                return tuple(rec(v, path) for v in t)
            return self.named(self.cache_spec(path, t))
        return rec(cache_shapes, ())
