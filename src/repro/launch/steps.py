"""Step builders for the dry-run and launchers: paper-faithful LoRA
train_step, prefill_step, serve (decode) step, and the multi-pod
fed_round step.  Each returns (fn, example_args, in_shardings)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import fed_spmd
from repro.configs.base import FedConfig
from repro.launch import specs as specs_mod
from repro.launch.sharding import ShardingPolicy
from repro.core import tasks
from repro.models import loss as losses
from repro.models.factory import build_model
from repro.optim import adam
from repro.peft import lora as lora_lib

LORA_RANK = 8
LORA_ALPHA = 32.0


def _named(policy, spec_tree):
    return jax.tree.map(lambda s: policy.named(s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     remat: str = "full", scan_layers: bool = True,
                     lora_rank: int = LORA_RANK, peft: bool = True):
    """Paper-faithful local fine-tune step: LoRA-only gradients, frozen
    base closed over as an argument (donated in production)."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    targets = lora_lib.default_targets(cfg)
    lt_shape = jax.eval_shape(
        lambda: lora_lib.init_lora(jax.random.PRNGKey(0), params_shape,
                                   targets, lora_rank))
    opt_shape = jax.eval_shape(adam.init, lt_shape)
    batch_shape = specs_mod.train_input_specs(cfg, shape)

    param_sh = policy.tree_shardings(params_shape)
    lt_sh = policy.tree_shardings(lt_shape)
    opt_sh = {"m": lt_sh, "v": lt_sh,
              "step": policy.named(P())}
    batch_sh = _named(policy, policy.batch_spec(batch_shape))

    def train_step(base, lt, opt, batch):
        def loss_fn(l):
            bound = lora_lib.bind(base, l, LORA_ALPHA, lora_rank)
            logits, aux = model.forward(bound, batch,
                                        scan_layers=scan_layers,
                                        remat=remat)
            # offset-aware LM loss (VLM image prefix shifts positions)
            loss, _ = tasks.generative_loss_fn(logits, batch)
            return loss + aux

        loss, grads = jax.value_and_grad(loss_fn)(lt)
        new_lt, new_opt = adam.update(grads, opt, lt, 1e-4)
        return new_lt, new_opt, loss

    args = (params_shape, lt_shape, opt_shape, batch_shape)
    shardings = (param_sh, lt_sh, opt_sh, batch_sh)
    return train_step, args, shardings


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       scan_layers: bool = True):
    """Inference prefill: full-sequence forward, last-position logits."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    batch_shape = specs_mod.train_input_specs(cfg, shape)
    param_sh = policy.tree_shardings(params_shape)
    batch_sh = _named(policy, policy.batch_spec(batch_shape))

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, scan_layers=scan_layers)
        return logits[:, -1, :]

    return prefill_step, (params_shape, batch_shape), (param_sh, batch_sh)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      scan_layers: bool = True):
    """Serve step: ONE new token against a seq_len-deep KV cache."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    cache_shape = specs_mod.abstract_cache(model, params_shape, shape)
    io = specs_mod.decode_input_specs(cfg, shape)
    param_sh = policy.tree_shardings(params_shape)
    cache_sh = policy.cache_shardings(cache_shape)
    GB = shape.global_batch
    tok_spec = P(policy.dp) if GB % max(policy.dp_size, 1) == 0 else P()
    tok_sh = policy.named(tok_spec)
    pos_sh = policy.named(P())

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    args = (params_shape, cache_shape, io["token"], io["pos"])
    shardings = (param_sh, cache_sh, tok_sh, pos_sh)
    return serve_step, args, shardings


def build_fed_round_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         n_clients: int = 2, n_local_steps: int = 1,
                         remat: str = "full", lora_rank: int = LORA_RANK):
    """Multi-pod federated round: clients on the ``pod`` axis, FedAvg as a
    cross-pod all-reduce (DESIGN SS2, core/fed_spmd.py)."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    targets = lora_lib.default_targets(cfg)
    lt_shape = jax.eval_shape(
        lambda: lora_lib.init_lora(jax.random.PRNGKey(0), params_shape,
                                   targets, lora_rank))
    opt_shape = jax.eval_shape(adam.init, lt_shape)
    # stack on the client axis
    stack = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), t)
    slt_shape, sopt_shape = stack(lt_shape), stack(opt_shape)
    per_client_batch = shape.global_batch // n_clients
    batch_shape = {"tokens": jax.ShapeDtypeStruct(
        (n_clients, n_local_steps, per_client_batch, shape.seq_len),
        jnp.int32)}

    fed = FedConfig(lora_rank=lora_rank, lora_alpha=LORA_ALPHA)
    round_step = fed_spmd.make_spmd_round(model, fed, task="generative")

    param_sh = policy.tree_shardings(params_shape)
    pod = ("pod",) if "pod" in mesh.axis_names else ()
    client_spec = lambda x: policy.named(
        P(pod, *([None] * x.ndim)))
    slt_sh = jax.tree.map(client_spec, lt_shape)
    sopt_sh = jax.tree.map(client_spec, opt_shape)
    batch_sh = {"tokens": policy.named(P(pod, None, ("data",), None))}
    args = (params_shape, slt_shape, sopt_shape, batch_shape)
    shardings = (param_sh, slt_sh, sopt_sh, batch_sh)
    return round_step, args, shardings


BUILDERS = {
    "train": build_train_step,
    "prefill": build_prefill_step,
    "decode": build_decode_step,
}


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               scan_layers: bool = True, remat: str = "full"):
    """Dispatch on the shape's mode."""
    if shape.mode == "train":
        return build_train_step(cfg, shape, mesh, remat=remat,
                                scan_layers=scan_layers)
    if shape.mode == "prefill":
        return build_prefill_step(cfg, shape, mesh, scan_layers=scan_layers)
    return build_decode_step(cfg, shape, mesh, scan_layers=scan_layers)
