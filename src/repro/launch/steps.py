"""Step builders for the dry-run and launchers: paper-faithful LoRA
train_step, prefill_step, serve (decode) step, and the multi-pod
fed_round step.  Each returns (fn, example_args, in_shardings)."""
from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, PrivacyConfig, ShapeConfig
from repro.core import fed_spmd
from repro.core import round_program
from repro.configs.base import FedConfig
from repro.launch import mesh as mesh_mod
from repro.launch import specs as specs_mod
from repro.launch.sharding import ShardingPolicy
from repro.core import tasks
from repro.models import loss as losses
from repro.models.factory import build_model
from repro.optim import adam
from repro.peft import lora as lora_lib

LORA_RANK = 8
LORA_ALPHA = 32.0


def _named(policy, spec_tree):
    return jax.tree.map(lambda s: policy.named(s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _policy_scoped(fn, cfg: ModelConfig):
    """Trace ``fn`` under the config's kernel policy: the dry-run/launch
    lowering path dispatches the LoRA/attention/KD-loss hot paths to the
    Pallas kernels exactly like the round engine does, so
    ``--kernel-policy pallas`` reaches the jitted step (ROADMAP leftover
    from the KernelPolicy PR)."""
    from repro.kernels import ops as kernel_ops

    @functools.wraps(fn)
    def scoped(*args, **kwargs):
        with kernel_ops.policy_scope(cfg.kernel_policy):
            return fn(*args, **kwargs)

    return scoped


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     remat: str = "full", scan_layers: bool = True,
                     lora_rank: int = LORA_RANK, peft: bool = True):
    """Paper-faithful local fine-tune step: LoRA-only gradients, frozen
    base closed over as an argument (donated in production)."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    targets = lora_lib.default_targets(cfg)
    lt_shape = jax.eval_shape(
        lambda: lora_lib.init_lora(jax.random.PRNGKey(0), params_shape,
                                   targets, lora_rank))
    opt_shape = jax.eval_shape(adam.init, lt_shape)
    batch_shape = specs_mod.train_input_specs(cfg, shape)

    param_sh = policy.tree_shardings(params_shape)
    lt_sh = policy.tree_shardings(lt_shape)
    opt_sh = {"m": lt_sh, "v": lt_sh,
              "step": policy.named(P())}
    batch_sh = _named(policy, policy.batch_spec(batch_shape))

    def train_step(base, lt, opt, batch):
        def loss_fn(l):
            bound = lora_lib.bind(base, l, LORA_ALPHA, lora_rank)
            logits, aux = model.forward(bound, batch,
                                        scan_layers=scan_layers,
                                        remat=remat)
            # offset-aware LM loss (VLM image prefix shifts positions)
            loss, _ = tasks.generative_loss_fn(logits, batch)
            return loss + aux

        loss, grads = jax.value_and_grad(loss_fn)(lt)
        new_lt, new_opt = adam.update(grads, opt, lt, 1e-4)
        return new_lt, new_opt, loss

    args = (params_shape, lt_shape, opt_shape, batch_shape)
    shardings = (param_sh, lt_sh, opt_sh, batch_sh)
    return _policy_scoped(train_step, cfg), args, shardings


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       scan_layers: bool = True):
    """Inference prefill: full-sequence forward, last-position logits."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    batch_shape = specs_mod.train_input_specs(cfg, shape)
    param_sh = policy.tree_shardings(params_shape)
    batch_sh = _named(policy, policy.batch_spec(batch_shape))

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, scan_layers=scan_layers)
        return logits[:, -1, :]

    return _policy_scoped(prefill_step, cfg), (params_shape, batch_shape), \
        (param_sh, batch_sh)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      scan_layers: bool = True):
    """Serve step: ONE new token against a seq_len-deep KV cache."""
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    cache_shape = specs_mod.abstract_cache(model, params_shape, shape)
    io = specs_mod.decode_input_specs(cfg, shape)
    param_sh = policy.tree_shardings(params_shape)
    cache_sh = policy.cache_shardings(cache_shape)
    GB = shape.global_batch
    tok_spec = P(policy.dp) if GB % max(policy.dp_size, 1) == 0 else P()
    tok_sh = policy.named(tok_spec)
    pos_sh = policy.named(P())

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    args = (params_shape, cache_shape, io["token"], io["pos"])
    shardings = (param_sh, cache_sh, tok_sh, pos_sh)
    return _policy_scoped(serve_step, cfg), args, shardings


def build_fed_round_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         n_clients: int = 2, n_local_steps: int = 1,
                         remat: str = "full", lora_rank: int = LORA_RANK,
                         framework: str = "fedllm",
                         privacy: PrivacyConfig = None,
                         shard_clients: bool = False,
                         cohort_size: int = 0, n_edges: int = 1,
                         robust_agg: str = "mean"):
    """Multi-pod federated round for any of the three frameworks, built
    from the SAME stage-specs the runtime pipeline runs
    (core/round_program.FrameworkProgram.spmd_round): clients on the
    mesh's client axes, server aggregation as a cross-client all-reduce
    (DESIGN SS2, core/fed_spmd.py).  ``framework`` selects the FedLLM
    FedAvg round, the KD knowledge round, or the Split round.

    ``shard_clients`` shards the stacked client axis over
    launch/mesh.client_axes (the ``pod`` axis on multi-pod meshes, the
    ``data`` axis otherwise) with explicit NamedShardings — the
    mesh-sharded SPMD path the runtime's SpmdExecutor uses given a
    mesh.  Without it, only a multi-pod mesh's ``pod`` axis carries the
    client dimension (the pre-refactor behavior).  For Split the client
    axis is *scanned* (shared server half), so the constraint pins the
    stacked client halves feeding the closing cc2 reduction instead.

    ``privacy`` threads PrivacyConfig into the lowered round: per-example
    DP-SGD clipping inside the local update (the fused clip kernel is in
    the traced program under ``kernel_policy="pallas"`` — dryrun verifies
    this), DP payload/activation noise from extra noise-key inputs, and
    the b3/c2 mechanisms of the KD/Split rounds.

    ``robust_agg`` swaps the closing client-axis reduction for the
    Byzantine-robust statistic (core/fed_spmd.robust_client_combine) —
    coordinate-wise median / trimmed mean / norm-clipped mean — in the
    lowered program, exactly as the runtime round does.

    ``cohort_size`` > 0 clamps the stacked client axis to one cohort:
    the compiled artifact under cohort streaming is the per-chunk
    program, re-invoked over the cohort stream by the host driver, so
    its memory footprint IS the round's peak regardless of the virtual
    population size.  ``n_edges`` > 1 lowers the FedLLM a4 reduce as
    the hierarchical per-edge partial sum + cross-edge tree reduce
    (core/fed_spmd.hierarchical_client_mean)."""
    if cohort_size and cohort_size > 0:
        n_clients = min(n_clients, cohort_size)
    model = build_model(cfg)
    policy = ShardingPolicy(mesh, cfg)
    params_shape = model.init_abstract(dtype=jnp.bfloat16)
    targets = lora_lib.default_targets(cfg)
    lt_shape = jax.eval_shape(
        lambda: lora_lib.init_lora(jax.random.PRNGKey(0), params_shape,
                                   targets, lora_rank))
    opt_shape = jax.eval_shape(adam.init, lt_shape)
    # stack on the client axis
    stack = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), t)
    slt_shape, sopt_shape = stack(lt_shape), stack(opt_shape)
    per_client_batch = max(shape.global_batch // n_clients, 1)

    def _stacked_batch(extra_label_keys: bool):
        inner = specs_mod.train_input_specs(
            cfg, ShapeConfig(shape.name, shape.seq_len, per_client_batch,
                             "train"))
        if extra_label_keys:
            inner["labels"] = jax.ShapeDtypeStruct((per_client_batch,),
                                                   jnp.int32)
            inner["lengths"] = jax.ShapeDtypeStruct((per_client_batch,),
                                                    jnp.int32)
        return jax.tree.map(lambda x: jax.ShapeDtypeStruct(
            (n_clients, n_local_steps) + x.shape, x.dtype), inner)

    keys_shape = jax.eval_shape(
        lambda: fed_spmd.split_keys(jax.random.PRNGKey(0), n_clients,
                                    n_local_steps))
    valid_shape = jax.ShapeDtypeStruct((n_clients, n_local_steps),
                                       jnp.bool_)
    weights_shape = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    param_sh = policy.tree_shardings(params_shape)
    pod = mesh_mod.client_axes(mesh) if shard_clients else (
        ("pod",) if "pod" in mesh.axis_names else ())
    client_spec = lambda x: policy.named(P(pod, *([None] * x.ndim)))
    slt_sh = jax.tree.map(client_spec, lt_shape)
    sopt_sh = jax.tree.map(client_spec, opt_shape)
    keys_sh = policy.named(P(pod, *([None] * (len(keys_shape.shape) - 1))))
    valid_sh = policy.named(P(pod, None))
    weights_sh = policy.named(P(pod))

    def _batch_sh(batch_shape, client_axis=pod):
        # the per-step batch dim can reuse ``data`` only when the client
        # axis doesn't already occupy it (shard_clients on a single-pod
        # mesh puts clients on ``data``)
        inner = ("data",) if "data" not in tuple(client_axis or ()) else None
        return jax.tree.map(lambda x: policy.named(P(
            client_axis, None, inner if inner and x.shape[2] % max(
                mesh.shape["data"], 1) == 0 else None,
            *([None] * (x.ndim - 3)))), batch_shape)

    privacy = privacy or PrivacyConfig()
    client_keys_shape = jax.eval_shape(
        lambda: jax.random.split(jax.random.PRNGKey(0), n_clients))
    ckeys_sh = policy.named(
        P(pod, *([None] * (len(client_keys_shape.shape) - 1))))

    # everything the per-framework builders share, by name
    ctx = SimpleNamespace(
        model=model, cfg=cfg, shape=shape, mesh=mesh, policy=policy,
        pod=pod, n_clients=n_clients, per_client_batch=per_client_batch,
        lora_rank=lora_rank, params_shape=params_shape, lt_shape=lt_shape,
        opt_shape=opt_shape, slt_shape=slt_shape, sopt_shape=sopt_shape,
        keys_shape=keys_shape, valid_shape=valid_shape,
        weights_shape=weights_shape, param_sh=param_sh, slt_sh=slt_sh,
        sopt_sh=sopt_sh, keys_sh=keys_sh, valid_sh=valid_sh,
        weights_sh=weights_sh, stacked_batch=_stacked_batch,
        batch_sh=_batch_sh, privacy=privacy,
        client_keys_shape=client_keys_shape, ckeys_sh=ckeys_sh,
        shard_clients=shard_clients, robust_agg=robust_agg)

    if framework == "fedllm":
        fed = FedConfig(lora_rank=lora_rank, lora_alpha=LORA_ALPHA,
                        privacy=privacy, robust_agg=robust_agg)
        round_step = round_program.FedLLMProgram.spmd_round(
            model, fed, task="generative", n_edges=n_edges)
        batch_shape = _stacked_batch(False)
        args = (params_shape, slt_shape, sopt_shape, batch_shape,
                keys_shape, valid_shape, weights_shape)
        shardings = (param_sh, slt_sh, sopt_sh, _batch_sh(batch_shape),
                     keys_sh, valid_sh, weights_sh)
        if privacy.noise_std > 0.0:
            # one payload-noise key per client slot (a3 upload boundary)
            args = args + (client_keys_shape,)
            shardings = shardings + (ckeys_sh,)
        return _policy_scoped(round_step, cfg), args, shardings
    if framework == "kd":
        return _build_kd_round(ctx)
    if framework == "split":
        return _build_split_round(ctx)
    raise ValueError(f"unknown federated framework {framework!r}")


def _build_kd_round(ctx):
    """KD-FedLLM round: one program from the KD stage-spec
    (core/round_program.KDProgram.spmd_round — vmapped b1 local update,
    batched b2 public logits, b4 client-axis knowledge reduction, b5
    server distillation, b6 global logits and vmapped b8 client
    distillation).  Classification task keeps the exchanged knowledge at
    n_classes dims (paper SSIII.B's framing of why KD favors
    classification)."""
    policy, shape = ctx.policy, ctx.shape
    fed = FedConfig(framework="kd", lora_rank=ctx.lora_rank,
                    lora_alpha=LORA_ALPHA, lora_dropout=0.0,
                    privacy=ctx.privacy, robust_agg=ctx.robust_agg)
    noised = ctx.privacy.noise_std > 0.0
    kd_round_core = round_program.KDProgram.spmd_round(
        ctx.model, fed, task="classification")

    batch_shape = ctx.stacked_batch(True)
    public_shape = {
        "tokens": jax.ShapeDtypeStruct(
            (ctx.per_client_batch, shape.seq_len), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((ctx.per_client_batch,), jnp.int32),
    }
    client_keys_shape = jax.eval_shape(
        lambda: jax.random.split(jax.random.PRNGKey(0), ctx.n_clients))
    server_key_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    lt_sh = policy.tree_shardings(ctx.lt_shape)
    opt_sh = {"m": lt_sh, "v": lt_sh, "step": policy.named(P())}
    pub_sh = jax.tree.map(
        lambda x: policy.named(P(
            ("data",) if x.shape[0] % max(ctx.mesh.shape["data"], 1) == 0
            else None, *([None] * (x.ndim - 1)))), public_shape)
    ckeys_sh = policy.named(
        P(ctx.pod, *([None] * (len(client_keys_shape.shape) - 1))))
    skey_sh = policy.named(P(*([None] * len(server_key_shape.shape))))
    args = (ctx.params_shape, ctx.slt_shape, ctx.sopt_shape, ctx.lt_shape,
            ctx.opt_shape, batch_shape, ctx.keys_shape, ctx.valid_shape,
            ctx.weights_shape, public_shape, client_keys_shape,
            server_key_shape)
    shardings = (ctx.param_sh, ctx.slt_sh, ctx.sopt_sh, lt_sh, opt_sh,
                 ctx.batch_sh(batch_shape), ctx.keys_sh, ctx.valid_sh,
                 ctx.weights_sh, pub_sh, ckeys_sh, skey_sh)
    if noised:
        # per-client b3 noise keys (upload-boundary mechanism)
        args = args + (ctx.client_keys_shape,)
        shardings = shardings + (ctx.ckeys_sh,)
    return _policy_scoped(kd_round_core, ctx.cfg), args, shardings


def _build_split_round(ctx):
    """Split-FedLLM round from the Split stage-spec: stacked client
    halves, shared server half scanned over the client axis, closing
    client-axis FedAvg.  With ``shard_clients`` the stacked client
    halves feeding the cc2 reduction are pinned to the mesh's client
    axes (the scan axis itself cannot shard — the server carry is
    sequential by the paper's schedule)."""
    from repro.core import split as split_mod
    from repro.launch.sharding import client_spec

    model, policy = ctx.model, ctx.policy
    fed = FedConfig(framework="split", lora_rank=ctx.lora_rank,
                    lora_alpha=LORA_ALPHA, lora_dropout=0.0,
                    privacy=ctx.privacy, robust_agg=ctx.robust_agg)
    sfns = split_mod.make_split_fns(model, fed, task="generative")
    L = sfns["n_client_groups"]
    client_sharding = (
        lambda nd: policy.named(client_spec(ctx.mesh, nd))) \
        if ctx.shard_clients else None
    round_step = round_program.SplitProgram.spmd_round(
        model, fed, task="generative", sfns=sfns,
        client_sharding=client_sharding)
    enc_dec = ctx.cfg.is_encoder_decoder
    base_c_shape, base_s_shape = jax.eval_shape(
        lambda b: split_mod.split_base(b, L, enc_dec), ctx.params_shape)
    c_shape, s_shape = jax.eval_shape(
        lambda t: split_mod.split_lora(t, L), ctx.lt_shape)
    s_opt_shape = jax.eval_shape(adam.init, s_shape)
    batch_shape = ctx.stacked_batch(False)
    base_c_sh = policy.tree_shardings(base_c_shape)
    base_s_sh = policy.tree_shardings(base_s_shape)
    c_sh = policy.tree_shardings(c_shape)
    s_sh = policy.tree_shardings(s_shape)
    s_opt_sh = {"m": s_sh, "v": s_sh, "step": policy.named(P())}
    # the client axis is scanned (shared server carry) — don't shard it
    keys_sh = policy.named(P(*([None] * len(ctx.keys_shape.shape))))
    valid_sh = policy.named(P(None, None))
    weights_sh = policy.named(P(None))
    batch_sh = ctx.batch_sh(batch_shape, client_axis=None)
    args = (base_c_shape, base_s_shape, c_shape, s_shape, s_opt_shape,
            batch_shape, ctx.keys_shape, ctx.valid_shape,
            ctx.weights_shape)
    shardings = (base_c_sh, base_s_sh, c_sh, s_sh, s_opt_sh, batch_sh,
                 keys_sh, valid_sh, weights_sh)
    if ctx.privacy.noise_std > 0.0:
        # (C, S) grid of c2 activation noise keys, scanned with the
        # batches (the client axis is scanned — no pod sharding)
        args = args + (ctx.keys_shape,)
        shardings = shardings + (
            policy.named(P(*([None] * len(ctx.keys_shape.shape)))),)
    return _policy_scoped(round_step, ctx.cfg), args, shardings


BUILDERS = {
    "train": build_train_step,
    "prefill": build_prefill_step,
    "decode": build_decode_step,
}


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               scan_layers: bool = True, remat: str = "full"):
    """Dispatch on the shape's mode."""
    if shape.mode == "train":
        return build_train_step(cfg, shape, mesh, remat=remat,
                                scan_layers=scan_layers)
    if shape.mode == "prefill":
        return build_prefill_step(cfg, shape, mesh, scan_layers=scan_layers)
    return build_decode_step(cfg, shape, mesh, scan_layers=scan_layers)
