"""Production mesh factories (charter: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(v5e pod); multi-pod adds a leading ``pod`` axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
