"""Production mesh factories (charter: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(v5e pod); multi-pod adds a leading ``pod`` axis (2 pods = 512 chips).

Compat: ``AxisType`` / ``jax.set_mesh`` only exist on newer jax; on
older releases we fall back to plain meshes and the ``Mesh`` context
manager so the launch layer keeps importing and compiling everywhere.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def activate_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on new jax; the ``Mesh`` context manager (same
    named-axis resolution for jit/shard_map) on older releases.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)  # pragma: no cover


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a dict on new jax and a
    one-element list of dicts on older releases — normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def data_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def client_axes(mesh) -> tuple:
    """Axes the round engine's *stacked client* dimension shards over:
    the dedicated ``pod`` axis on multi-pod meshes (one simulated client
    per pod slice), else the ``data`` axis.  launch/sharding.py builds
    the explicit client-axis NamedShardings from this."""
    return ("pod",) if "pod" in mesh.axis_names else ("data",)


def client_axis_size(mesh) -> int:
    size = 1
    for a in client_axes(mesh):
        size *= mesh.shape[a]
    return size


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def n_edges(mesh) -> int:
    """Edge-aggregator count of the two-hop client -> edge -> server
    hierarchy: one edge per pod on a multi-pod mesh, else a single
    (degenerate, flat) edge.  The cohort-streaming driver derives its
    hierarchical ledger accounting — and the compiled round its
    hierarchical client-axis reduce — from this."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("pod", 1))
