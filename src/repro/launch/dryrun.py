"""Multi-pod dry-run (charter deliverable e): lower + compile every
(architecture x input-shape) combination against the production meshes
and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k [--multi-pod] [--step auto|train|prefill|decode|fed_round]
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --step fed_round --fed-framework kd
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json

The XLA_FLAGS line below MUST run before any other jax-importing code:
jax locks the device count at first backend init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro.configs.registry import ARCHS, get_config          # noqa: E402
from repro.configs.shapes import SHAPES, shape_supported, skip_reason  # noqa: E402
from repro.launch import steps as steps_mod                   # noqa: E402
from repro.launch.mesh import (activate_mesh, cost_analysis_dict,  # noqa: E402
                               make_production_mesh)
from repro.models import common                               # noqa: E402
from repro.roofline import collectives as coll_mod            # noqa: E402

GiB = 2**30

ASSIGNED = [a for a in ARCHS if not a.startswith("gpt2")]


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            step: str = "auto", remat: str = "full",
            scan_layers: bool = True, verbose: bool = True,
            parse_collectives: bool = True,
            fed_framework: str = "fedllm", kernel_policy: str = None,
            client_ranks=None, aggregation: str = "sync",
            dp_clip: float = 0.0, dp_noise_multiplier: float = 0.0,
            secure_agg: bool = False, backend: str = "spmd",
            shard_clients: bool = False, n_clients: int = None,
            population: str = None, cohort_size: int = None,
            robust_agg: str = "mean", faults: str = None) -> dict:
    from repro.configs.base import PrivacyConfig

    if step == "fed_round" and backend not in ("spmd", "cohort"):
        raise ValueError(
            "--step fed_round lowers the SPMD round program (the "
            "sequential backend is a python loop with no single-program "
            "artifact); use --backend spmd or cohort")
    # --population dirichlet:<alpha>:<n_virtual>: the cohort-streaming
    # scenario.  The compiled artifact is the per-cohort chunk program
    # (the host driver re-invokes it over the stream), so the stacked
    # client axis is clamped to one cohort — the virtual population
    # size only shows up in the cohort count.
    n_virtual = None
    pop_alpha = None
    if population:
        try:
            kind, alpha_s, nv = population.split(":")
            if kind != "dirichlet":
                raise ValueError(kind)
            pop_alpha, n_virtual = float(alpha_s), int(nv)
        except ValueError:
            raise ValueError(
                f"bad --population {population!r} (expected "
                "dirichlet:<alpha>:<n_virtual>, e.g. dirichlet:0.5:100000)")
        if not cohort_size:
            raise ValueError("--population requires --cohort-size (the "
                             "virtual fleet streams cohort by cohort)")
    if cohort_size:
        n_clients = cohort_size if n_clients is None \
            else min(n_clients, cohort_size)
    # --faults dropout:0.2,byzantine:2,...: fault injection is host-side
    # (faults/plan.py draws from the seed tree and corrupts payloads at
    # the upload seam), so it never changes the compiled round — the
    # record keeps the scenario; --robust-agg DOES change the program
    # (the closing client-axis reduction becomes the robust statistic).
    fault_cfg = None
    if faults:
        from repro.configs.base import FaultConfig
        keymap = {"dropout": ("dropout_rate", float),
                  "straggler": ("straggler_rate", float),
                  "delay": ("straggler_delay", int),
                  "byzantine": ("byzantine", int),
                  "mode": ("byzantine_mode", str),
                  "scale": ("byzantine_scale", float)}
        kw = {}
        try:
            for item in faults.split(","):
                k, v = item.split(":")
                field, cast = keymap[k]
                kw[field] = cast(v)
        except (ValueError, KeyError):
            raise ValueError(
                f"bad --faults {faults!r} (expected comma-separated "
                f"key:value with keys {sorted(keymap)}, e.g. "
                "dropout:0.2,byzantine:2)")
        fault_cfg = FaultConfig(**kw)
    cfg = get_config(arch)
    if kernel_policy:
        # thread ModelConfig.kernel_policy through the lowering path —
        # launch/steps traces every step under the config's policy scope
        cfg = dataclasses.replace(cfg, kernel_policy=kernel_policy)
    shape = SHAPES[shape_name]
    privacy = PrivacyConfig(dp_clip=dp_clip,
                            dp_noise_multiplier=dp_noise_multiplier,
                            secure_agg=secure_agg)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "step": shape.mode if step == "auto" else step,
           "kernel_policy": cfg.kernel_policy}
    if step == "fed_round":
        rec["fed_framework"] = fed_framework
        rec["backend"] = backend
        if population:
            rec["population"] = population
            rec["dirichlet_alpha"] = pop_alpha
            rec["n_virtual_clients"] = n_virtual
        if cohort_size:
            rec["cohort_size"] = cohort_size
            rec["cohort_count"] = -(-(n_virtual or n_clients
                                      or 2) // cohort_size)
        # async reuses the same per-bucket local-update programs — the
        # arrival schedule is host-side — so the compile artifact is the
        # sync one; the record keeps the axis visible in sweeps.
        rec["aggregation"] = aggregation
        if robust_agg != "mean":
            rec["robust_agg"] = robust_agg
        if fault_cfg is not None:
            rec["faults"] = faults
            rec["fault_config"] = dataclasses.asdict(fault_cfg)
        if client_ranks:
            rec["client_ranks"] = list(client_ranks)
        if privacy.enabled:
            # per-config privacy record: the knobs plus the secure-agg
            # setup bytes (host-side overlay — not part of the program).
            # The sync masking cohort is the whole client set, which for
            # the dry-run build is len(client_ranks) or the builder's
            # 2-client default.
            rec["dp_clip"] = dp_clip
            rec["dp_noise_multiplier"] = dp_noise_multiplier
            rec["secure_agg"] = secure_agg
            if secure_agg:
                from repro.privacy.secure_agg import key_exchange_bytes
                cohort = len(client_ranks) if client_ranks else 2
                up, down = key_exchange_bytes(cohort)
                rec["secagg_key_bytes_per_client"] = up + down

    # Heterogeneous client_ranks compile one stacked program per rank
    # bucket (core/rounds_spmd.py runs exactly these per-bucket
    # programs).  Split buckets only contiguous equal-rank runs — the
    # shared server half is carried client-after-client — so its
    # program set comes from rank_segments, like the runtime's.
    builds = [("", {})]
    if step == "fed_round" and client_ranks:
        from repro.core import fed_spmd
        group = fed_spmd.rank_segments if fed_framework == "split" \
            else fed_spmd.rank_buckets
        sigs = []                     # distinct (rank, size) signatures —
        for rank, cis in group(list(client_ranks)):   # jit reuses repeats
            if (rank, len(cis)) not in sigs:
                sigs.append((rank, len(cis)))
        builds = [(f"rank{rank}x{n}", {"n_clients": n, "lora_rank": rank})
                  for rank, n in sigs]

    if step == "auto" and not shape_supported(cfg, shape):
        rec["status"] = "SKIP"
        rec["reason"] = skip_reason(cfg, shape)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    if step == "fed_round" and shard_clients:
        from repro.launch.mesh import client_axes, client_axis_size
        # default the client count to the client-axis extent so the
        # stacked axis shards 1:1 over the mesh's client axes
        if n_clients is None:
            n_clients = client_axis_size(mesh)
        rec["client_axis"] = list(client_axes(mesh))
        rec["shard_clients"] = True
    rec["status"] = "OK"
    programs = []
    with activate_mesh(mesh):
        for label, build_kw in builds:
            common.enable_shard_hints(True)
            try:
                t0 = time.time()
                if step == "fed_round":
                    fed_kw = dict(framework=fed_framework, privacy=privacy,
                                  shard_clients=shard_clients,
                                  robust_agg=robust_agg)
                    if n_clients is not None:
                        fed_kw["n_clients"] = n_clients
                    if cohort_size:
                        fed_kw["cohort_size"] = cohort_size
                    if backend == "cohort" and fed_framework == "fedllm":
                        # hierarchical a4 reduce: one edge per pod
                        from repro.launch.mesh import n_edges as mesh_edges
                        ne = mesh_edges(mesh)
                        if ne > 1:
                            fed_kw["n_edges"] = ne
                            rec["n_edges"] = ne
                    fed_kw.update(build_kw)
                    fn, args, shardings = steps_mod.build_fed_round_step(
                        cfg, shape, mesh, remat=remat, **fed_kw)
                else:
                    fn, args, shardings = steps_mod.build_step(
                        cfg, shape, mesh, scan_layers=scan_layers,
                        remat=remat)
                jitted = jax.jit(fn, in_shardings=shardings)
                lowered = jitted.lower(*args)
                t_low = time.time() - t0
                compiled = lowered.compile()
                t_comp = time.time() - t0 - t_low
            finally:
                common.enable_shard_hints(False)

            from repro.kernels import ops as kernel_ops
            if step == "fed_round" and privacy.dp_clip > 0 \
                    and fed_framework in ("fedllm", "kd") \
                    and kernel_ops.resolve(cfg.kernel_policy) == "pallas":
                # verify the DP machinery actually reached the jitted
                # round: under the pallas policy the fused clip kernel
                # must appear in the traced jaxpr by name.  (Split's
                # threat surface is the c2 activation clip+noise — jnp
                # row math inside split_step, no per-example grads — so
                # there is no clip kernel to find in its round.  The
                # extra trace only runs for this pallas gate; under xla
                # the kernel can never appear, so nothing to check.)
                txt = str(jax.make_jaxpr(fn)(*args))
                in_jaxpr = "dp_clip_mean_rows" in txt
                rec["dp_clip_kernel_in_jaxpr"] = in_jaxpr
                if not in_jaxpr:
                    raise RuntimeError(
                        "--dp-clip with --kernel-policy pallas but the "
                        "dp_clip_mean_rows kernel is not in the traced "
                        "jaxpr — the DP-SGD path did not reach the "
                        "jitted round")

            if step == "fed_round" and robust_agg in ("median",
                                                      "trimmed_mean"):
                # verify the robust statistic reached the jitted round:
                # both median and trimmed mean lower through a sort on
                # the stacked client axis, which plain FedAvg never emits
                txt = str(jax.make_jaxpr(fn)(*args))
                in_jaxpr = "sort" in txt
                rec["robust_sort_in_jaxpr"] = in_jaxpr
                if not in_jaxpr:
                    raise RuntimeError(
                        f"--robust-agg {robust_agg} but no sort appears "
                        "in the traced jaxpr — the robust reduction did "
                        "not reach the jitted round")

            if step == "fed_round" and shard_clients:
                # acceptance gate: the client-axis NamedSharding must be
                # visible in the lowered program (GSPMD spells it
                # 'devices=[C,...'; the Shardy partitioner spells it
                # '#sdy.sharding<..{"<axis>"}..>')
                from repro.launch.mesh import (client_axes,
                                               client_axis_size)
                txt = lowered.as_text()
                size = client_axis_size(mesh)
                ax = client_axes(mesh)[0]
                in_hlo = (f"devices=[{size}," in txt) or (
                    "sdy.sharding" in txt and f'{{"{ax}"}}' in txt)
                rec["client_axis_sharding_in_hlo"] = in_hlo
                if not in_hlo:
                    raise RuntimeError(
                        "--shard-clients but no client-axis sharding is "
                        "visible in the lowered program — the stacked "
                        "client dimension did not reach the mesh's "
                        f"{ax!r} axis")

            ma = compiled.memory_analysis()
            ca = cost_analysis_dict(compiled)
            prog = {
                "lower_s": round(t_low, 2),
                "compile_s": round(t_comp, 2),
                "arg_gib_per_dev": round(ma.argument_size_in_bytes / GiB, 3),
                "temp_gib_per_dev": round(ma.temp_size_in_bytes / GiB, 3),
                "out_gib_per_dev": round(ma.output_size_in_bytes / GiB, 3),
                "hlo_flops": ca.get("flops", 0.0),
                "hlo_bytes": ca.get("bytes accessed", 0.0),
            }
            if parse_collectives:
                try:
                    cb = coll_mod.collective_bytes(compiled.as_text())
                    prog["collective_bytes"] = cb
                    prog["collective_total"] = sum(cb.values())
                except Exception as e:                 # pragma: no cover
                    prog["collective_error"] = str(e)
            if label:
                prog["bucket"] = label
            programs.append(prog)

    if len(programs) == 1:
        # the common single-program case keeps the original flat schema
        # (incl. the per-kind collective_bytes dict / collective_error)
        rec.update(programs[0])
    else:
        # roll per-bucket programs up into the flat record the sweep
        # tooling reads: summed time/flops, peak per-device memory
        for k in ("lower_s", "compile_s", "hlo_flops", "hlo_bytes"):
            rec[k] = round(sum(p[k] for p in programs), 2)
        for k in ("arg_gib_per_dev", "temp_gib_per_dev", "out_gib_per_dev"):
            rec[k] = max(p[k] for p in programs)
        if any("collective_total" in p for p in programs):
            cb = {}
            for p in programs:
                for kind, nbytes in p.get("collective_bytes", {}).items():
                    cb[kind] = cb.get(kind, 0) + nbytes
            rec["collective_bytes"] = cb
            rec["collective_total"] = sum(p.get("collective_total", 0)
                                          for p in programs)
        errs = [f"{p.get('bucket', i)}: {p['collective_error']}"
                for i, p in enumerate(programs) if "collective_error" in p]
        if errs:                                       # pragma: no cover
            rec["collective_error"] = "; ".join(errs)
        rec["bucket_programs"] = programs
    if cohort_size and step == "fed_round":
        # the per-cohort peak: one chunk program's whole footprint —
        # under cohort streaming this bounds the round regardless of
        # the virtual population size
        rec["cohort_peak_gib_per_dev"] = round(
            rec.get("arg_gib_per_dev", 0.0)
            + rec.get("temp_gib_per_dev", 0.0)
            + rec.get("out_gib_per_dev", 0.0), 3)
    if verbose:
        print(f"[{rec['status']}] {arch} x {shape_name} ({rec['mesh']}, "
              f"{rec['step']}): compile={rec.get('compile_s', '-')}s "
              f"args={rec.get('arg_gib_per_dev', '-')}GiB "
              f"temp={rec.get('temp_gib_per_dev', '-')}GiB "
              f"coll={rec.get('collective_total', 0)/1e9:.2f}GB"
              + (f" buckets={len(programs)}" if len(programs) > 1 else ""))
        if cohort_size and step == "fed_round":
            print(f"    cohorts: {rec.get('cohort_count')} x "
                  f"{cohort_size} clients"
                  + (f" of {n_virtual} virtual" if n_virtual else "")
                  + f", per-cohort peak "
                  f"{rec['cohort_peak_gib_per_dev']}GiB/dev")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (assigned arch x shape), both meshes")
    ap.add_argument("--step", default="auto",
                    choices=["auto", "train", "prefill", "decode",
                             "fed_round"])
    ap.add_argument("--fed-framework", default="fedllm",
                    choices=["fedllm", "kd", "split"],
                    help="which paper framework --step fed_round compiles")
    ap.add_argument("--backend", default="spmd",
                    choices=["sequential", "spmd", "cohort"],
                    help="round-engine execution backend for --step "
                         "fed_round; spmd compiles the whole stacked "
                         "round, cohort compiles the per-cohort chunk "
                         "program the streaming driver re-invokes")
    ap.add_argument("--shard-clients", action="store_true",
                    help="shard the stacked client axis of --step "
                         "fed_round over the mesh's client axes "
                         "(launch/mesh.client_axes) with explicit "
                         "NamedShardings, and verify the sharding is "
                         "visible in the lowered program")
    ap.add_argument("--n-clients", type=int, default=None,
                    help="client count for --step fed_round (default 2, "
                         "or the client-axis extent with "
                         "--shard-clients); with --cohort-size this is "
                         "an alias clamped to one cohort")
    ap.add_argument("--population", default=None,
                    help="virtual client population for --step fed_round "
                         "as dirichlet:<alpha>:<n_virtual>, e.g. "
                         "dirichlet:0.5:100000 — the cohort-streaming "
                         "scenario (requires --cohort-size; the record "
                         "gets cohort_count and the per-cohort peak "
                         "memory)")
    ap.add_argument("--cohort-size", type=int, default=None,
                    help="clients per streamed cohort for --step "
                         "fed_round: the compiled chunk program stacks "
                         "exactly one cohort, whatever the population "
                         "size")
    ap.add_argument("--kernel-policy", default=None,
                    choices=["xla", "pallas", "auto"],
                    help="override ModelConfig.kernel_policy for the "
                         "lowered step (pallas = fused fwd+bwd kernels)")
    ap.add_argument("--client-ranks", default=None,
                    help="comma-separated per-client LoRA ranks for "
                         "--step fed_round, e.g. 4,8,8,16; compiles one "
                         "stacked program per rank bucket")
    ap.add_argument("--aggregation", default="sync",
                    choices=["sync", "async"],
                    help="aggregation schedule axis to record; async "
                         "reuses the per-bucket local-update programs "
                         "(arrival scheduling is host-side)")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="per-example L2 clip for --step fed_round: the "
                         "fused DP-SGD clip kernel enters the jitted "
                         "round (verified in the traced jaxpr under "
                         "--kernel-policy pallas)")
    ap.add_argument("--dp-noise-multiplier", type=float, default=0.0,
                    help="Gaussian noise multiplier sigma (payload noise "
                         "stddev = sigma * clip); adds the per-client "
                         "noise-key inputs to the lowered round")
    ap.add_argument("--robust-agg", default="mean",
                    choices=["mean", "median", "trimmed_mean", "norm_clip"],
                    help="Byzantine-robust closing reduction for --step "
                         "fed_round; median/trimmed_mean are verified to "
                         "reach the traced jaxpr (they lower via sort)")
    ap.add_argument("--faults", default=None,
                    help="seeded fault-injection scenario to record, as "
                         "comma-separated key:value — e.g. "
                         "dropout:0.2,byzantine:2,mode:sign_flip "
                         "(keys: dropout, straggler, delay, byzantine, "
                         "mode, scale); host-side, does not change the "
                         "compiled program")
    ap.add_argument("--secure-agg", action="store_true",
                    help="record the simulated secure-aggregation "
                         "overlay (host-side masking; key-exchange "
                         "bytes in the record)")
    ap.add_argument("--remat", default="full", choices=["none", "full"])
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args()

    records = []
    if args.all:
        for arch in ASSIGNED:
            for shape_name in SHAPES:
                for mp in (False, True):
                    records.append(run_one(arch, shape_name, mp,
                                           remat=args.remat,
                                           scan_layers=not args.no_scan))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        ranks = tuple(int(r) for r in args.client_ranks.split(",")) \
            if args.client_ranks else None
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            records.append(run_one(args.arch, args.shape, mp,
                                   step=args.step, remat=args.remat,
                                   scan_layers=not args.no_scan,
                                   fed_framework=args.fed_framework,
                                   kernel_policy=args.kernel_policy,
                                   client_ranks=ranks,
                                   aggregation=args.aggregation,
                                   dp_clip=args.dp_clip,
                                   dp_noise_multiplier=(
                                       args.dp_noise_multiplier),
                                   secure_agg=args.secure_agg,
                                   backend=args.backend,
                                   shard_clients=args.shard_clients,
                                   n_clients=args.n_clients,
                                   population=args.population,
                                   cohort_size=args.cohort_size,
                                   robust_agg=args.robust_agg,
                                   faults=args.faults))

    ok = sum(r["status"] == "OK" for r in records)
    skip = sum(r["status"] == "SKIP" for r in records)
    print(f"\n{ok} OK, {skip} SKIP(policy), {len(records)-ok-skip} FAIL "
          f"of {len(records)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    if len(records) - ok - skip:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
