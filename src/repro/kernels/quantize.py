"""Per-row symmetric int8 quantization Pallas TPU kernel — the wire
format of Split-FedLLM activation/gradient transfer (paper SSIV.C.2).

One pass: per-row absmax -> scale -> rounded int8 payload.  Grid over
row blocks; whole feature dim per block (d_model <= 18432 fits VMEM
comfortably at (8, d) fp32 tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "br", "interpret"))
def quantize_rows(x, *, bits: int = 8, br: int = 8, interpret: bool = True):
    """x: (R, C) -> (q int8 (R, C), scale fp32 (R, 1))."""
    R, C = x.shape
    br = min(br, R)
    assert R % br == 0
    qmax = float((1 << (bits - 1)) - 1)
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)
