"""Per-row symmetric quantization Pallas TPU kernels — the wire formats
of Split-FedLLM activation/gradient transfer (paper SSIV.C.2) and
KD-FedLLM logit upload (SSIV.B.2).

- ``quantize_rows``: one pass per row block: absmax -> scale -> rounded
  int8 payload.  Grid over row blocks; whole feature dim per block
  (d_model <= 18432 fits VMEM comfortably at (8, d) fp32 tiles).
- ``quantize_pack4_rows``: int4 variant that packs two nibbles per byte
  inside the kernel, so the emitted payload IS the wire payload.
- ``topk_quantize_rows``: fused top-k + int8 row kernel for the KD b3
  logit upload — selection, scaling and rounding all happen on-device in
  one pass (k rounds of masked row-max; no sort, Mosaic-friendly), so
  the client's knowledge upload never bounces through host memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "br", "interpret"))
def quantize_rows(x, *, bits: int = 8, br: int = 8, interpret: bool = True):
    """x: (R, C) -> (q int8 (R, C), scale fp32 (R, 1))."""
    R, C = x.shape
    br = min(br, R)
    assert R % br == 0
    qmax = float((1 << (bits - 1)) - 1)
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, C), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)


# --------------------------------------------------------------------------- #
# int4 with in-kernel nibble packing
# --------------------------------------------------------------------------- #
def _pack4_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (br, C)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 7.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -7.0, 7.0).astype(jnp.int32)
    u = q & 0xF                                           # two's-comp nibble
    br, C = u.shape
    pair = u.reshape(br, C // 2, 2)
    q_ref[...] = (pair[:, :, 0] | (pair[:, :, 1] << 4)).astype(jnp.uint8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def quantize_pack4_rows(x, *, br: int = 8, interpret: bool = True):
    """x: (R, C), C even -> (packed uint8 (R, C//2), scale fp32 (R, 1)).

    Two int4 values per byte: even column in the low nibble, odd column
    in the high nibble — the exact transmittable Split-FedLLM payload."""
    R, C = x.shape
    assert C % 2 == 0, C
    br = min(br, R)
    assert R % br == 0
    return pl.pallas_call(
        _pack4_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, C // 2), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C // 2), jnp.uint8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)


# --------------------------------------------------------------------------- #
# Fused top-k + int8 (KD b3 logit upload)
# --------------------------------------------------------------------------- #
def _topk_kernel(x_ref, v_ref, i_ref, s_ref, *, k: int, qmax: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, C)
    br, C = x.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (br, C), 1)

    def body(t, carry):
        xc, vals, idxs = carry
        m = jnp.max(xc, axis=-1, keepdims=True)           # (br, 1)
        idx = jnp.min(jnp.where(xc == m, col, C), axis=-1,
                      keepdims=True)                      # first argmax
        vals = jax.lax.dynamic_update_slice(vals, m, (0, t))
        idxs = jax.lax.dynamic_update_slice(idxs, idx, (0, t))
        xc = jnp.where(col == idx, NEG_INF, xc)
        return xc, vals, idxs

    _, vals, idxs = jax.lax.fori_loop(
        0, k, body, (x, jnp.zeros((br, k), jnp.float32),
                     jnp.zeros((br, k), jnp.int32)))
    absmax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    v_ref[...] = jnp.clip(jnp.round(vals / scale), -qmax,
                          qmax).astype(jnp.int8)
    i_ref[...] = idxs
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "bits", "br", "interpret"))
def topk_quantize_rows(x, *, k: int, bits: int = 8, br: int = 8,
                       interpret: bool = True):
    """x: (R, C) -> (q int8 (R, k), idx int32 (R, k), scale fp32 (R, 1)).

    Top-k by value (ties broken toward the lower index, matching
    ``jax.lax.top_k``), then symmetric per-row quantization of the k
    selected values.  Selection is k rounds of masked row-max — O(kC)
    VPU work, no sort — so the whole b3 compression runs as one kernel.
    """
    R, C = x.shape
    assert 0 < k <= C, (k, C)
    br = min(br, R)
    assert R % br == 0
    qmax = float((1 << (bits - 1)) - 1)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k, qmax=qmax),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, k), lambda i: (i, 0)),
                   pl.BlockSpec((br, k), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, k), jnp.int8),
                   jax.ShapeDtypeStruct((R, k), jnp.int32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)
