"""RG-LRU recurrence Pallas TPU kernel:  h_t = a_t * h_{t-1} + b_t.

RecurrentGemma's temporal hot loop.  Gates (a, b = sqrt(1-a^2)*i*x) are
computed by dense matmuls outside (models/rglru.py); the kernel runs the
elementwise recurrence with the (1, bw) hidden state resident in VMEM
across time blocks — no HBM round-trip per step, unlike an XLA while
loop which spills the carry.

Grid (B, W/bw, S/bt), time innermost; within a block a sequential fori
over bt steps (elementwise VPU work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, hf_ref, h_ref, *, bt: int,
            nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (bt, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[0])
    h_ref[0] = h

    @pl.when(ti == nt - 1)
    def _finish():
        hf_ref[...] = h_ref[...].astype(hf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bw", "bt", "interpret"))
def rglru_scan(a, b, h0, *, bw: int = 128, bt: int = 128,
               interpret: bool = True):
    """a, b: (B, S, W); h0: (B, W).  Returns (h (B,S,W), h_final (B,W))."""
    B, S, W = a.shape
    bw = min(bw, W)
    bt = min(bt, S)
    assert W % bw == 0 and S % bt == 0
    nt = S // bt
    kernel = functools.partial(_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(B, W // bw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda bb, w, t: (bb, t, w)),
            pl.BlockSpec((1, bt, bw), lambda bb, w, t: (bb, t, w)),
            pl.BlockSpec((1, bw), lambda bb, w, t: (bb, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bw), lambda bb, w, t: (bb, t, w)),
            pl.BlockSpec((1, bw), lambda bb, w, t: (bb, w)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, W), jnp.float32),
                   jax.ShapeDtypeStruct((B, W), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
