"""Model-facing jit'd wrappers around the Pallas kernels: reshape from
model-layer layouts to kernel layouts, choose block shapes, and select
interpret mode (Python emulation on CPU; compiled on real TPU).

This module is also the **kernel dispatch layer**: ``ModelConfig``
carries a ``kernel_policy`` (``"xla" | "pallas" | "auto"``) which the
round engine / model facade resolve into an ambient policy scope here
(mirroring models/common's ``shard_hints`` pattern).  The LoRA
projection (peft/lora.lora_apply), attention (models/attention) and the
KD loss (models/loss.kd_kl) consult ``use_pallas()`` at trace time, so
every framework trains *through* the fused fwd+bwd kernels when the
policy selects them — the three hot-path kernels are differentiable via
``jax.custom_vjp`` (kernels/{lora_matmul,kd_loss,flash_attention}).

``auto`` resolves to ``pallas`` on a real TPU backend and ``xla``
everywhere else (interpret-mode Pallas is a correctness tool, not a fast
path).
"""
from __future__ import annotations

import contextlib
import math

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip as _dp
from repro.kernels import flash_attention as _fa
from repro.kernels import kd_loss as _kd
from repro.kernels import lora_matmul as _lm
from repro.kernels import quantize as _q
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw

INTERPRET = jax.default_backend() != "tpu"

# --------------------------------------------------------------------------- #
# Kernel policy (ModelConfig.kernel_policy -> ambient dispatch scope)
# --------------------------------------------------------------------------- #
POLICIES = ("xla", "pallas", "auto")
_ACTIVE = "xla"


def resolve(policy: str) -> str:
    """``auto`` -> ``pallas`` on TPU, ``xla`` elsewhere."""
    if policy not in POLICIES:
        raise ValueError(f"unknown kernel_policy {policy!r} "
                         f"(expected one of {POLICIES})")
    if policy == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return policy


@contextlib.contextmanager
def policy_scope(policy: str):
    """Make ``policy`` the ambient kernel policy while tracing/executing.

    Entered by core/rounds.run_federated (covers both execution backends)
    and by models/factory.Model.forward (direct model use)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = resolve(policy)
    try:
        yield
    finally:
        _ACTIVE = prev


def use_pallas() -> bool:
    return _ACTIVE == "pallas"


# --------------------------------------------------------------------------- #
# Block-shape selection
# --------------------------------------------------------------------------- #
def fit_block(n: int, cap: int, align: int = 128) -> int:
    """Block size for a dim of ``n`` under a VMEM budget of ``cap``:
    the largest divisor of ``n`` that is <= ``cap``, preferring
    lane-aligned (multiple-of-``align``) divisors.  This is the
    chunk-size fallback for dims the default block doesn't divide:
    e.g. V=151936 with bv=2048 yields 128 (aligned) rather than
    silently streaming the whole vocab through one VMEM block — the
    memory wall the kernels exist to avoid.

    Dims with only pathological divisors (primes, 50257-style vocabs
    whose best divisor would shred the grid) fall back to the whole dim
    as a single block rather than a degenerate tiny-block grid: a
    too-large block is slow-but-correct, a width-1 grid of thousands of
    steps is neither."""
    cap = min(cap, n)
    best = 1
    for d in range(cap, 0, -1):
        if n % d == 0:
            if d % align == 0:
                return d
            if best == 1:
                best = d
    # no aligned divisor: accept the plain one unless it is degenerate
    if best >= max(cap // 8, 1):
        return best
    return n


def lora_matmul(x, w, a, b, block_m: int = 128, block_k: int = 512,
                block_n: int = 128):
    """x: (..., K) -> (..., N) with LoRA fused.  Pads M to the tile.

    Differentiable end-to-end (fused Pallas backward kernels)."""
    *lead, K = x.shape
    M = math.prod(lead)
    xf = x.reshape(M, K)
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = _lm.lora_matmul(xf, w, a, b, bm=bm, bk=fit_block(K, block_k),
                          bn=fit_block(w.shape[1], block_n),
                          interpret=INTERPRET)
    if pad:
        out = out[:M]
    return out.reshape(*lead, w.shape[1])


def mha_attention(q, k, v, causal: bool = True, window: int = 0,
                  q_offset: int = 0, bq: int = 128, bkv: int = 128):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) -> (B, Sq, H, D).

    Differentiable (recompute-based flash backward, GQA-aware)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              q_offset=q_offset, bq=fit_block(Sq, bq),
                              bkv=fit_block(Skv, bkv),
                              interpret=INTERPRET)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def kd_loss(teacher, student, temperature: float = 1.0, mask=None,
            br: int = 128, bv: int = 2048):
    """teacher/student: (..., V) -> scalar mean KD loss (masked).

    Differentiable w.r.t. both logit sets (streaming backward kernel)."""
    V = teacher.shape[-1]
    t = teacher.reshape(-1, V)
    s = student.reshape(-1, V)
    R = t.shape[0]
    brr = min(br, R)
    pad = (-R) % brr
    if pad:
        t = jnp.pad(t, ((0, pad), (0, 0)))
        s = jnp.pad(s, ((0, pad), (0, 0)))
    rows = _kd.kd_loss_rows(t, s, temperature=temperature, br=brr,
                            bv=fit_block(V, bv), interpret=INTERPRET)[:R, 0]
    if mask is not None:
        m = mask.reshape(-1).astype(jnp.float32)
        return jnp.sum(rows * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(rows)


def rglru(a, b, h0, bw: int = 128, bt: int = 128):
    """a, b: (B, S, W); h0: (B, W) -> (h (B,S,W), h_final)."""
    W = a.shape[-1]
    S = a.shape[1]
    return _rg.rglru_scan(a, b, h0, bw=fit_block(W, bw),
                          bt=fit_block(S, bt), interpret=INTERPRET)


def rwkv6(r, k, v, logw, u, bt: int = 64):
    """(B, S, H, D) layout + u (H, D) -> (y (B,S,H,D), S_f (B,H,D,D))."""
    B, S, H, D = r.shape
    flat = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    uf = jnp.tile(u, (B, 1))
    y, Sf = _rw.rwkv6_scan(flat(r), flat(k), flat(v), flat(logw), uf,
                           bt=fit_block(S, bt), interpret=INTERPRET)
    return (y.reshape(B, H, S, D).transpose(0, 2, 1, 3),
            Sf.reshape(B, H, D, D))


def quantize(x, bits: int = 8, br: int = 8):
    """x: (..., C) -> (q int8, scale fp32 (..., 1))."""
    *lead, C = x.shape
    R = math.prod(lead)
    xf = x.reshape(R, C)
    q, sc = _q.quantize_rows(xf, bits=bits, br=fit_block(R, br, align=1),
                             interpret=INTERPRET)
    return q.reshape(*lead, C), sc.reshape(*lead, 1)


def quantize_pack4(x, br: int = 8):
    """x: (..., C) -> (packed uint8 (..., ceil(C/2)), scale (..., 1)).

    Odd C is zero-padded by one column before packing."""
    *lead, C = x.shape
    R = math.prod(lead)
    xf = x.reshape(R, C)
    if C % 2:
        xf = jnp.pad(xf, ((0, 0), (0, 1)))
    q, sc = _q.quantize_pack4_rows(xf, br=fit_block(R, br, align=1),
                                   interpret=INTERPRET)
    return q.reshape(*lead, (C + 1) // 2), sc.reshape(*lead, 1)


def clip_mean_rows(g, clip: float, block_p: int = 2048):
    """g: (B, P) stacked per-example grads -> (P,) fp32 mean of the
    per-example L2-clipped rows — the DP-SGD clip-scale-accumulate step
    (privacy/dp.py).  Under the ``pallas`` policy this is the fused
    two-phase kernel (kernels/dp_clip.py); otherwise the XLA reference.
    Forward-only (runs on gradients; never differentiated through)."""
    from repro.kernels import ref as _ref
    if not use_pallas():
        return _ref.clip_mean_rows_ref(g, clip)
    P = g.shape[1]
    return _dp.dp_clip_mean_rows(g, clip=float(clip),
                                 bp=fit_block(P, block_p),
                                 interpret=INTERPRET)[0]


def topk_quantize(x, k: int, bits: int = 8, br: int = 8):
    """x: (..., V) -> (q int8 (..., k), idx int32 (..., k), scale (..., 1)).

    The fused KD b3 upload: selection + quantization on-device.  Under
    the ``pallas`` policy this is the one-pass Pallas kernel; otherwise
    the XLA reference (lax.top_k + symmetric rounding) — bit-identical
    outputs (tests/test_kernels.py), still device-resident, but without
    interpret-mode emulation cost on CPU."""
    from repro.kernels import ref as _ref
    if not use_pallas():
        return _ref.topk_quantize_rows_ref(x, k, bits)
    *lead, V = x.shape
    R = math.prod(lead)
    xf = x.reshape(R, V)
    q, idx, sc = _q.topk_quantize_rows(xf, k=k, bits=bits,
                                       br=fit_block(R, br, align=1),
                                       interpret=INTERPRET)
    return (q.reshape(*lead, k), idx.reshape(*lead, k),
            sc.reshape(*lead, 1))
