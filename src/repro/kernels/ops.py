"""Model-facing jit'd wrappers around the Pallas kernels: reshape from
model-layer layouts to kernel layouts, choose block shapes, and select
interpret mode (Python emulation on CPU; compiled on real TPU).

These are the TPU hot paths; the XLA paths in models/ remain the default
for CPU execution and for the SPMD dry-run lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kd_loss as _kd
from repro.kernels import lora_matmul as _lm
from repro.kernels import quantize as _q
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw

INTERPRET = jax.default_backend() != "tpu"


def lora_matmul(x, w, a, b, block_m: int = 128, block_k: int = 512,
                block_n: int = 128):
    """x: (..., K) -> (..., N) with LoRA fused.  Pads M to the tile."""
    *lead, K = x.shape
    M = 1
    for s in lead:
        M *= s
    xf = x.reshape(M, K)
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = _lm.lora_matmul(xf, w, a, b, bm=bm, bk=min(block_k, K),
                          bn=min(block_n, w.shape[1]), interpret=INTERPRET)
    if pad:
        out = out[:M]
    return out.reshape(*lead, w.shape[1])


def mha_attention(q, k, v, causal: bool = True, window: int = 0,
                  q_offset: int = 0, bq: int = 128, bkv: int = 128):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              q_offset=q_offset, bq=bq, bkv=bkv,
                              interpret=INTERPRET)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def kd_loss(teacher, student, temperature: float = 1.0, mask=None,
            br: int = 128, bv: int = 2048):
    """teacher/student: (..., V) -> scalar mean KD loss (masked)."""
    V = teacher.shape[-1]
    t = teacher.reshape(-1, V)
    s = student.reshape(-1, V)
    R = t.shape[0]
    brr = min(br, R)
    pad = (-R) % brr
    if pad:
        t = jnp.pad(t, ((0, pad), (0, 0)))
        s = jnp.pad(s, ((0, pad), (0, 0)))
    bvv = bv if V % bv == 0 else V          # fall back to single chunk
    rows = _kd.kd_loss_rows(t, s, temperature=temperature, br=brr, bv=bvv,
                            interpret=INTERPRET)[:R, 0]
    if mask is not None:
        m = mask.reshape(-1).astype(jnp.float32)
        return jnp.sum(rows * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(rows)


def rglru(a, b, h0, bw: int = 128, bt: int = 128):
    """a, b: (B, S, W); h0: (B, W) -> (h (B,S,W), h_final)."""
    W = a.shape[-1]
    bww = bw if W % bw == 0 else W
    S = a.shape[1]
    btt = bt if S % bt == 0 else S
    return _rg.rglru_scan(a, b, h0, bw=bww, bt=btt, interpret=INTERPRET)


def rwkv6(r, k, v, logw, u, bt: int = 64):
    """(B, S, H, D) layout + u (H, D) -> (y (B,S,H,D), S_f (B,H,D,D))."""
    B, S, H, D = r.shape
    flat = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    uf = jnp.tile(u, (B, 1))
    btt = bt if S % bt == 0 else S
    y, Sf = _rw.rwkv6_scan(flat(r), flat(k), flat(v), flat(logw), uf,
                           bt=btt, interpret=INTERPRET)
    return (y.reshape(B, H, S, D).transpose(0, 2, 1, 3),
            Sf.reshape(B, H, D, D))


def quantize(x, bits: int = 8, br: int = 8):
    """x: (..., C) -> (q int8, scale fp32 (..., 1))."""
    *lead, C = x.shape
    R = 1
    for s in lead:
        R *= s
    xf = x.reshape(R, C)
    brr = br if R % br == 0 else 1
    q, sc = _q.quantize_rows(xf, bits=bits, br=brr, interpret=INTERPRET)
    return q.reshape(*lead, C), sc.reshape(*lead, 1)
