"""Fused LoRA matmul Pallas TPU kernel:  y = x @ W + (x @ A) @ B.

The PEFT hot spot of every framework in the paper: with LoRA bound to a
projection, XLA materializes the (T, r) intermediate x@A in HBM between
two small matmuls.  This kernel keeps the rank-r panel (A-block, B-block
and the (bm, r) running x@A accumulator) resident in VMEM alongside the
main (bm, bn) accumulator, so the low-rank path costs no extra HBM
traffic — the W tiles dominate, exactly as in the un-adapted matmul.

Grid (m, n, k), k innermost; fp32 accumulators; MXU-aligned tiles
(multiples of 128 on m/n, 512 on k by default).

Differentiable via ``jax.custom_vjp``: the backward pass is two more
fused Pallas kernels that preserve the forward's no-extra-HBM-traffic
property for the low-rank path —

  * ``dx = g @ Wᵀ + (g @ Bᵀ) @ Aᵀ`` reads W/A/B in their *native* layout
    (contracting on the N axis; no XLA transposes) and keeps the (bm, r)
    ``g @ Bᵀ`` panel resident in VMEM, emitting it as the ``gb`` residual
    for the dA kernel.
  * ``dW = xᵀg``, ``dA = xᵀ(gBᵀ)`` and ``dB = (xA)ᵀg`` are three
    *separate* pallas calls, each keeping its accumulator VMEM-resident
    across the m sweep.  Keeping dW out of the dA/dB calls matters: in
    PEFT training W is a frozen closed-over constant, its cotangent is
    dropped, and jaxpr DCE then eliminates the whole dense (K, N)
    reduction — the backward costs only dx plus the two rank-r panels,
    mirroring the forward's no-extra-HBM-traffic property.  The (M, r)
    ``x @ A`` panel is saved from the forward instead of being
    recomputed — it is rank-r, i.e. free relative to any (M, K) or
    (K, N) residual.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fwd_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, xa_out_ref, acc_ref,
                xa_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        x, w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    xa_ref[...] += jax.lax.dot(
        x, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        low = jax.lax.dot(xa_ref[...], b_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + low).astype(o_ref.dtype)
        xa_out_ref[...] = xa_ref[...]


def _fwd_call(x, w, a, b, bm: int, bk: int, bn: int, interpret: bool):
    """Returns (y (M, N), xa (M, r)) — xa is the resident x@A panel."""
    M, K = x.shape
    _, N = w.shape
    r = a.shape[-1]
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    nm, nn, nk = M // bm, N // bn, K // bk
    return pl.pallas_call(
        functools.partial(_fwd_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bm, r), lambda i, j, k: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, N), x.dtype),
                   jax.ShapeDtypeStruct((M, r), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)


# --------------------------------------------------------------------------- #
# Backward kernels
# --------------------------------------------------------------------------- #
def _dx_kernel(g_ref, w_ref, a_ref, b_ref, dx_ref, gb_out_ref, acc_ref,
               gb_ref, *, nn: int):
    """dx[m, k] = Σ_n g[m, n] w[k, n]  +  (Σ_n g[m, n] b[r, n]) aᵀ[r, k].

    Grid (m, k, n), n innermost.  W/A/B are read in their native (K, N) /
    (K, r) / (r, N) layouts — the contraction runs over the N axis, so no
    host/XLA transpose is ever materialized.  The (bm, r) g@Bᵀ panel is
    emitted once (at k-block 0) as the residual for the dA kernel.
    """
    j = pl.program_id(1)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    g = g_ref[...].astype(jnp.float32)                      # (bm, bn)
    acc_ref[...] += jax.lax.dot_general(
        g, w_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (bm, bk)
    gb_ref[...] += jax.lax.dot_general(
        g, b_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (bm, r)

    @pl.when(n == nn - 1)
    def _finish():
        low = jax.lax.dot_general(
            gb_ref[...], a_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bm, bk)
        dx_ref[...] = (acc_ref[...] + low).astype(dx_ref.dtype)

        @pl.when(j == 0)
        def _emit_gb():
            gb_out_ref[...] = gb_ref[...]


def _dx_call(g, w, a, b, bm: int, bk: int, bn: int, interpret: bool,
             out_dtype):
    M, N = g.shape
    K = w.shape[0]
    r = a.shape[-1]
    nm, nk, nn = M // bm, K // bk, N // bn
    return pl.pallas_call(
        functools.partial(_dx_kernel, nn=nn),
        grid=(nm, nk, nn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, j, n: (j, n)),
            pl.BlockSpec((bk, r), lambda i, j, n: (j, 0)),
            pl.BlockSpec((r, bn), lambda i, j, n: (0, n)),
        ],
        out_specs=[pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
                   pl.BlockSpec((bm, r), lambda i, j, n: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), out_dtype),
                   jax.ShapeDtypeStruct((M, r), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(g, w, a, b)


def _dw_kernel(x_ref, g_ref, dw_ref, accw_ref, *, nm: int):
    """dW[k, n] = Σ_m x[m, k] g[m, n].  Grid (k, n, m), m innermost.

    dW lives in its OWN pallas call (not fused with dA/dB) so that when
    W is a frozen closed-over constant — every PEFT step in this repo —
    the dropped cotangent lets jaxpr DCE remove this whole dense (K, N)
    reduction from the backward."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        accw_ref[...] = jnp.zeros_like(accw_ref)

    accw_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), g_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (bk, bn)

    @pl.when(t == nm - 1)
    def _finish():
        dw_ref[...] = accw_ref[...].astype(dw_ref.dtype)


def _dw_call(x, g, bm: int, bk: int, bn: int, interpret: bool, w_dtype):
    M, K = x.shape
    N = g.shape[1]
    nk, nn, nm = K // bk, N // bn, M // bm
    return pl.pallas_call(
        functools.partial(_dw_kernel, nm=nm),
        grid=(nk, nn, nm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (t, i)),
            pl.BlockSpec((bm, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), w_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g)


def _panel_grad_kernel(lhs_ref, panel_ref, out_ref, acc_ref, *, nm: int):
    """out[l, r] = Σ_m lhs[m, l] panel[m, r] — the shared shape of the
    rank-r grads dA = xᵀ(gBᵀ) and dB = ((xA)ᵀ g)ᵀ-style reductions.
    Grid (l, m), m innermost; the (bl, r) accumulator stays resident."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32), panel_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (bl, r)

    @pl.when(t == nm - 1)
    def _finish():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _panel_grad_call(lhs, panel, bm: int, bl: int, interpret: bool,
                     out_dtype):
    """lhs (M, L), panel (M, r) fp32 -> (L, r)."""
    M, L = lhs.shape
    r = panel.shape[-1]
    nl, nm = L // bl, M // bm
    return pl.pallas_call(
        functools.partial(_panel_grad_kernel, nm=nm),
        grid=(nl, nm),
        in_specs=[pl.BlockSpec((bm, bl), lambda i, t: (t, i)),
                  pl.BlockSpec((bm, r), lambda i, t: (t, 0))],
        out_specs=pl.BlockSpec((bl, r), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, r), out_dtype),
        scratch_shapes=[pltpu.VMEM((bl, r), jnp.float32)],
        interpret=interpret,
    )(lhs, panel)


# --------------------------------------------------------------------------- #
# custom_vjp plumbing
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _lora_matmul(x, w, a, b, bm, bk, bn, interpret):
    y, _ = _fwd_call(x, w, a, b, bm, bk, bn, interpret)
    return y


def _lora_matmul_fwd(x, w, a, b, bm, bk, bn, interpret):
    y, xa = _fwd_call(x, w, a, b, bm, bk, bn, interpret)
    return y, (x, w, a, b, xa)


def _lora_matmul_bwd(bm, bk, bn, interpret, res, g):
    x, w, a, b, xa = res
    g = g.astype(x.dtype)
    dx, gb = _dx_call(g, w, a, b, bm, bk, bn, interpret, x.dtype)
    dw = _dw_call(x, g, bm, bk, bn, interpret, w.dtype)
    da = _panel_grad_call(x, gb, bm, bk, interpret, a.dtype)
    db = _panel_grad_call(g, xa, bm, bn, interpret, b.dtype).T
    return dx, dw, da, db.astype(b.dtype)


_lora_matmul.defvjp(_lora_matmul_fwd, _lora_matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def lora_matmul(x, w, a, b, *, bm: int = 128, bk: int = 512, bn: int = 128,
                interpret: bool = True):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N).

    Scale (alpha/r) is expected folded into ``b`` (peft/lora.bind).
    Differentiable: ``jax.grad`` through this runs the fused Pallas
    backward kernels (dx / dW / dA / dB)."""
    M, K = x.shape
    _, N = w.shape
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    return _lora_matmul(x, w, a, b, bm, bk, bn, interpret)
