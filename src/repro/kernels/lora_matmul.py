"""Fused LoRA matmul Pallas TPU kernel:  y = x @ W + (x @ A) @ B.

The PEFT hot spot of every framework in the paper: with LoRA bound to a
projection, XLA materializes the (T, r) intermediate x@A in HBM between
two small matmuls.  This kernel keeps the rank-r panel (A-block, B-block
and the (bm, r) running x@A accumulator) resident in VMEM alongside the
main (bm, bn) accumulator, so the low-rank path costs no extra HBM
traffic — the W tiles dominate, exactly as in the un-adapted matmul.

Grid (m, n, k), k innermost; fp32 accumulators; MXU-aligned tiles
(multiples of 128 on m/n, 512 on k by default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        x, w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    xa_ref[...] += jax.lax.dot(
        x, a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        low = jax.lax.dot(xa_ref[...], b_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + low).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def lora_matmul(x, w, a, b, *, bm: int = 128, bk: int = 512, bn: int = 128,
                interpret: bool = True):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N).

    Scale (alpha/r) is expected folded into ``b`` (peft/lora.bind)."""
    M, K = x.shape
    _, N = w.shape
    r = a.shape[-1]
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    nm, nn, nk = M // bm, N // bn, K // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)
