"""Fused DP-SGD clip-scale-accumulate Pallas TPU kernel.

The hot loop of client-side DP-SGD (privacy/dp.py): given a client's
stacked per-example LoRA gradients flattened to a (B, P) matrix, emit
the mean of the per-example-clipped rows

    out[p] = (1/B) * sum_b g[b, p] * min(1, C / ||g[b, :]||_2)

in one pass over HBM per phase.  Two pallas calls share the work:

  * ``_norm_kernel`` — grid over P blocks, accumulating the (B, 1)
    per-example squared norms in the revisited output block (fp32
    accumulation regardless of input dtype — the dtype-safe guard the
    bf16 trees need lives in the scale computation, not the leaves).
  * ``_clip_acc_kernel`` — grid over P blocks again: load the (B, bp)
    gradient block and the finished (B, 1) norms, scale each row by
    ``min(1, C / max(norm, eps))`` and reduce the example axis to a
    (1, bp) output block.  Clip, scale and accumulate are fused — the
    (B, P) per-example gradients are never re-materialized scaled.

Forward-only semantics by design (no ``custom_vjp``): the kernel runs
*on* gradients, after ``jax.grad``, so nothing ever differentiates
through it.  Dispatch lives in kernels/ops.clip_mean_rows (kernel under
the ``pallas`` policy, kernels/ref.clip_mean_rows_ref under ``xla``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.optim.clip import EPS   # one eps for host, ref and kernel


def _norm_kernel(g_ref, n_ref):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)

    g = g_ref[...].astype(jnp.float32)
    n_ref[...] += jnp.sum(g * g, axis=-1, keepdims=True)


def _clip_acc_kernel(g_ref, n_ref, o_ref, *, clip: float, inv_b: float):
    g = g_ref[...].astype(jnp.float32)                     # (B, bp)
    norm = jnp.sqrt(n_ref[...])                            # (B, 1)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, EPS))
    o_ref[...] = jnp.sum(g * scale, axis=0,
                         keepdims=True) * jnp.float32(inv_b)


@functools.partial(jax.jit, static_argnames=("clip", "bp", "interpret"))
def dp_clip_mean_rows(g, *, clip: float, bp: int = 2048,
                      interpret: bool = True):
    """g: (B, P) stacked per-example grads -> (1, P) fp32 mean of rows
    clipped to L2 norm ``clip``.  ``P % bp == 0`` (kernels/ops pads)."""
    B, P = g.shape
    bp = min(bp, P)
    assert P % bp == 0, (P, bp)
    grid = (P // bp,)
    norms = pl.pallas_call(
        _norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((B, bp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((B, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(g)
    return pl.pallas_call(
        functools.partial(_clip_acc_kernel, clip=clip, inv_b=1.0 / B),
        grid=grid,
        in_specs=[pl.BlockSpec((B, bp), lambda i: (0, i)),
                  pl.BlockSpec((B, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, P), jnp.float32),
        interpret=interpret,
    )(g, norms)
