"""Chunked-vocab KD distillation loss Pallas TPU kernel.

KD-FedLLMs' hot spot on generative tasks (DESIGN SS2): the distillation
loss KL(softmax(t/T) || softmax(s/T)) over vocabularies of 151k-256k
entries.  Materializing both (rows, V) logit tensors plus softmaxes in
fp32 is the memory wall; this kernel streams vocab chunks through VMEM
keeping only five (br, 1) running statistics per row:

    m_t, z_t   — online logsumexp of teacher
    m_s, z_s   — online logsumexp of student
    u          — running  sum_j e^{t_j - m_t} (t_j - s_j)

    KL = u/z_t - (m_t + log z_t) + (m_s + log z_s),  x T^2

Grid (rows/br, V/bv), vocab innermost.

Differentiable via ``jax.custom_vjp``: the forward emits the five row
statistics as residuals (5 floats per row — nothing (R, V)-shaped is
saved), and the backward streams the same vocab chunks a second time,
reconstructing the chunk's teacher/student probabilities from the saved
statistics instead of materializing them:

    dL/dt_j = g · T · p_j (log p_j - log q_j - KL)
    dL/ds_j = g · T · (q_j - p_j)

with p = softmax(t/T), q = softmax(s/T).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(t_ref, s_ref, o_ref, mt_ref, zt_ref, ms_ref, zs_ref, u_ref,
                *, inv_temp: float, t2: float, nv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG_INF)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        zt_ref[...] = jnp.zeros_like(zt_ref)
        zs_ref[...] = jnp.zeros_like(zs_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    t = t_ref[...].astype(jnp.float32) * inv_temp       # (br, bv)
    s = s_ref[...].astype(jnp.float32) * inv_temp

    # teacher online LSE + cross term
    mt_new = jnp.maximum(mt_ref[...], jnp.max(t, axis=1, keepdims=True))
    at = jnp.exp(mt_ref[...] - mt_new)
    et = jnp.exp(t - mt_new)
    zt_ref[...] = zt_ref[...] * at + jnp.sum(et, axis=1, keepdims=True)
    u_ref[...] = u_ref[...] * at + jnp.sum(et * (t - s), axis=1,
                                           keepdims=True)
    mt_ref[...] = mt_new

    # student online LSE
    ms_new = jnp.maximum(ms_ref[...], jnp.max(s, axis=1, keepdims=True))
    as_ = jnp.exp(ms_ref[...] - ms_new)
    zs_ref[...] = zs_ref[...] * as_ + jnp.sum(jnp.exp(s - ms_new), axis=1,
                                              keepdims=True)
    ms_ref[...] = ms_new

    @pl.when(vi == nv - 1)
    def _finish():
        kl = (u_ref[...] / zt_ref[...]
              - (mt_ref[...] + jnp.log(zt_ref[...]))
              + (ms_ref[...] + jnp.log(zs_ref[...])))
        o_ref[...] = (kl * t2).astype(o_ref.dtype)


def _fwd_call(teacher, student, temperature: float, br: int, bv: int,
              interpret: bool):
    """Returns (rows (R, 1), mt, zt, ms, zs, u — each (R, 1) fp32).

    The five running statistics live in the output blocks themselves
    (block index (i, 0) is j-independent, so each stays VMEM-resident
    across the whole vocab sweep) — they double as the VJP residuals.
    """
    R, V = teacher.shape
    assert R % br == 0 and V % bv == 0, (R, V, br, bv)
    kernel = functools.partial(_fwd_kernel, inv_temp=1.0 / temperature,
                               t2=temperature * temperature, nv=V // bv)
    stat = jax.ShapeDtypeStruct((R, 1), jnp.float32)
    stat_spec = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(R // br, V // bv),
        in_specs=[pl.BlockSpec((br, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bv), lambda i, j: (i, j))],
        out_specs=[stat_spec] * 6,
        out_shape=[stat] * 6,
        interpret=interpret,
    )(teacher, student)


# --------------------------------------------------------------------------- #
# Backward kernel
# --------------------------------------------------------------------------- #
def _bwd_kernel(t_ref, s_ref, mt_ref, zt_ref, ms_ref, zs_ref, u_ref, g_ref,
                dt_ref, ds_ref, *, inv_temp: float, temp: float):
    t = t_ref[...].astype(jnp.float32) * inv_temp       # (br, bv)
    s = s_ref[...].astype(jnp.float32) * inv_temp
    lzt = mt_ref[...] + jnp.log(zt_ref[...])            # (br, 1) teacher LSE
    lzs = ms_ref[...] + jnp.log(zs_ref[...])
    logp = t - lzt
    logq = s - lzs
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    kl = u_ref[...] / zt_ref[...] - lzt + lzs           # unscaled KL (br, 1)
    g = g_ref[...] * temp                               # d(T^2·KL)/dt~ · T⁻¹
    dt_ref[...] = (g * p * (logp - logq - kl)).astype(dt_ref.dtype)
    ds_ref[...] = (g * (q - p)).astype(ds_ref.dtype)


def _bwd_call(teacher, student, stats, g, temperature: float, br: int,
              bv: int, interpret: bool):
    R, V = teacher.shape
    kernel = functools.partial(_bwd_kernel, inv_temp=1.0 / temperature,
                               temp=temperature)
    stat_spec = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(R // br, V // bv),
        in_specs=[pl.BlockSpec((br, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bv), lambda i, j: (i, j))]
        + [stat_spec] * 6,
        out_specs=[pl.BlockSpec((br, bv), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bv), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((R, V), teacher.dtype),
                   jax.ShapeDtypeStruct((R, V), student.dtype)],
        interpret=interpret,
    )(teacher, student, *stats, g)


# --------------------------------------------------------------------------- #
# custom_vjp plumbing
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _kd_loss_rows(teacher, student, temperature, br, bv, interpret):
    return _fwd_call(teacher, student, temperature, br, bv, interpret)[0]


def _kd_loss_rows_fwd(teacher, student, temperature, br, bv, interpret):
    rows, *stats = _fwd_call(teacher, student, temperature, br, bv,
                             interpret)
    return rows, (teacher, student, tuple(stats))


def _kd_loss_rows_bwd(temperature, br, bv, interpret, res, g):
    teacher, student, stats = res
    dt, ds = _bwd_call(teacher, student, stats, g.astype(jnp.float32),
                       temperature, br, bv, interpret)
    return dt, ds


_kd_loss_rows.defvjp(_kd_loss_rows_fwd, _kd_loss_rows_bwd)


@functools.partial(jax.jit, static_argnames=("temperature", "br", "bv",
                                              "interpret"))
def kd_loss_rows(teacher, student, *, temperature: float = 1.0,
                 br: int = 128, bv: int = 2048, interpret: bool = True):
    """teacher/student: (R, V) logits -> per-row KL (R, 1), already x T^2.

    Mean over rows (with masking) is applied by the ops wrapper.
    Differentiable w.r.t. both logit sets (streaming backward kernel)."""
    R, V = teacher.shape
    br = min(br, R)
    bv = min(bv, V)
    return _kd_loss_rows(teacher, student, temperature, br, bv, interpret)
