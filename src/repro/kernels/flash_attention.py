"""Flash attention Pallas TPU kernel: online-softmax blockwise attention
with causal masking, sliding windows (mixtral/recurrentgemma local
attention) and GQA via index-mapped KV head sharing.

Layout: q (BH, Sq, D), k/v (BKV, Skv, D) with BH = B*H, BKV = B*KV.
Grid (BH, nq, nkv), kv innermost; the (bq, D) output accumulator and the
online-softmax (m, l) statistics live in VMEM scratch across kv steps.
Fully-masked (q-block, kv-block) pairs are skipped with pl.when — for
causal attention that's half the work; for a sliding window all blocks
outside the band.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bkv: int,
            nkv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq + q_offset          # absolute position of first query
    kv_start = ki * bkv
    # block-level reachability (skip fully-masked tiles)
    reachable = True
    if causal:
        reachable = kv_start <= q_start + bq - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, kv_start + bkv - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "n_q_heads", "bq", "bkv", "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    n_q_heads: int = 0, bq: int = 128, bkv: int = 128,
                    q_offset: int = 0, interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BKV, Skv, D).  GQA when BKV < BH: kv head
    index = bh//G with G = BH//BKV (requires contiguous (b, h) layout).

    Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BH // BKV
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, nkv = Sq // bq, Skv // bkv
    kernel = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window, bq=bq,
        bkv=bkv, nkv=nkv, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
