"""Flash attention Pallas TPU kernel: online-softmax blockwise attention
with causal masking, sliding windows (mixtral/recurrentgemma local
attention) and GQA via index-mapped KV head sharing.

Layout: q (BH, Sq, D), k/v (BKV, Skv, D) with BH = B*H, BKV = B*KV.
Grid (BH, nq, nkv), kv innermost; the (bq, D) output accumulator and the
online-softmax (m, l) statistics live in VMEM scratch across kv steps.
Fully-masked (q-block, kv-block) pairs are skipped with pl.when — for
causal attention that's half the work; for a sliding window all blocks
outside the band.

Differentiable via ``jax.custom_vjp`` with the standard recompute-based
flash backward: the forward saves only (q, k, v, o, lse) — nothing
(Sq, Skv)-shaped — and the backward replays the score blocks from q/k
plus the per-row logsumexp:

    p   = exp(q·kᵀ·scale − lse)          (masked, recomputed per block)
    dv  = pᵀ do
    ds  = p (do·vᵀ − D),   D = rowsum(do ∘ o)
    dq  = scale · ds k      (dq kernel: grid (BH, nq, nkv))
    dk  = scale · dsᵀ q     (dkv kernel: grid (BKV, nkv, G·nq) — the
                             innermost axis walks every q block of every
                             query head sharing the kv head, so GQA's
                             head-group sum happens in the VMEM
                             accumulator, not in HBM)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(q_start, kv_start, bq: int, bkv: int, causal: bool,
                window: int):
    """(bq, bkv) boolean attend-mask for one (q-block, kv-block) pair."""
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, kv_pos <= q_pos)
    if window > 0:
        mask = jnp.logical_and(mask, kv_pos > q_pos - window)
    return mask


def _block_reachable(q_start, kv_start, bq: int, bkv: int, causal: bool,
                     window: int):
    """Scalar predicate: does this (q-block, kv-block) pair attend at all?"""
    reachable = True
    if causal:
        reachable = kv_start <= q_start + bq - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, kv_start + bkv - 1 > q_start - window)
    return reachable


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, scale: float, causal: bool, window: int, bq: int,
                bkv: int, nkv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq + q_offset          # absolute position of first query
    kv_start = ki * bkv

    @pl.when(_block_reachable(q_start, kv_start, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_mask(q_start, kv_start, bq, bkv, causal, window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))


def _fwd_call(q, k, v, causal: bool, window: int, q_offset: int, bq: int,
              bkv: int, interpret: bool):
    """Returns (o (BH, Sq, D), lse (BH, Sq, 1) fp32)."""
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BH // BKV
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, nkv = Sq // bq, Skv // bkv
    kernel = functools.partial(
        _fwd_kernel, scale=D ** -0.5, causal=causal, window=window, bq=bq,
        bkv=bkv, nkv=nkv, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
                   pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, Sq, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------- #
# Backward kernels
# --------------------------------------------------------------------------- #
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref, dq_ref,
               acc_ref, *, scale: float, causal: bool, window: int,
               bq: int, bkv: int, nkv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq + q_offset
    kv_start = ki * bkv

    @pl.when(_block_reachable(q_start, kv_start, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_mask(q_start, kv_start, bq, bkv, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0]), 0.0)
        dp = jax.lax.dot_general(
            do_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = p * (dp - dd_ref[0])                         # (bq, bkv)
        acc_ref[...] += jax.lax.dot(ds, k,
                                    preferred_element_type=jnp.float32)

    @pl.when(ki == nkv - 1)
    def _finish():
        dq_ref[0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref, dk_ref,
                dv_ref, acck_ref, accv_ref, *, scale: float, causal: bool,
                window: int, bq: int, bkv: int, nq: int, nt: int,
                q_offset: int):
    ki = pl.program_id(1)
    t = pl.program_id(2)                                  # g * nq + qi

    @pl.when(t == 0)
    def _init():
        acck_ref[...] = jnp.zeros_like(acck_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    q_start = (t % nq) * bq + q_offset
    kv_start = ki * bkv

    @pl.when(_block_reachable(q_start, kv_start, bq, bkv, causal, window))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        do = do_ref[0].astype(jnp.float32)                # (bq, D)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _block_mask(q_start, kv_start, bq, bkv, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0]), 0.0)
        accv_ref[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bkv, D)
        dp = jax.lax.dot_general(
            do, v_ref[0].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - dd_ref[0])                         # (bq, bkv)
        acck_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bkv, D)

    @pl.when(t == nt - 1)
    def _finish():
        dk_ref[0] = (acck_ref[...] * scale).astype(dk_ref.dtype)
        dv_ref[0] = accv_ref[...].astype(dv_ref.dtype)


def _bwd_call(q, k, v, o, lse, do, causal: bool, window: int, q_offset: int,
              bq: int, bkv: int, interpret: bool):
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BH // BKV
    nq, nkv = Sq // bq, Skv // bkv
    scale = D ** -0.5
    # D_i = rowsum(do ∘ o): elementwise + reduce — XLA, nothing (Sq,Skv)
    dd = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1,
                 keepdims=True)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, nkv=nkv,
                          q_offset=q_offset),
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dd)

    nt = G * nq
    q_spec = pl.BlockSpec((1, bq, D),
                          lambda b, j, t: (b * G + t // nq, t % nq, 0))
    row_spec = pl.BlockSpec((1, bq, 1),
                            lambda b, j, t: (b * G + t // nq, t % nq, 0))
    kv_spec = pl.BlockSpec((1, bkv, D), lambda b, j, t: (b, j, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, nq=nq, nt=nt,
                          q_offset=q_offset),
        grid=(BKV, nkv, nt),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=[kv_spec, kv_spec],
        out_shape=[jax.ShapeDtypeStruct((BKV, Skv, D), k.dtype),
                   jax.ShapeDtypeStruct((BKV, Skv, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bkv, D), jnp.float32),
                        pltpu.VMEM((bkv, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dd)
    return dq, dk, dv


# --------------------------------------------------------------------------- #
# custom_vjp plumbing
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, bq, bkv, interpret):
    return _fwd_call(q, k, v, causal, window, q_offset, bq, bkv,
                     interpret)[0]


def _flash_fwd(q, k, v, causal, window, q_offset, bq, bkv, interpret):
    o, lse = _fwd_call(q, k, v, causal, window, q_offset, bq, bkv,
                       interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, q_offset, bq, bkv, interpret, res, do):
    q, k, v, o, lse = res
    return _bwd_call(q, k, v, o, lse, do, causal, window, q_offset, bq,
                     bkv, interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "n_q_heads", "bq", "bkv", "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    n_q_heads: int = 0, bq: int = 128, bkv: int = 128,
                    q_offset: int = 0, interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BKV, Skv, D).  GQA when BKV < BH: kv head
    index = bh//G with G = BH//BKV (requires contiguous (b, h) layout).

    Returns (BH, Sq, D).  Differentiable: ``jax.grad`` through this runs
    the recompute-based flash backward kernels (dq + GQA-aware dk/dv)."""
    _, Sq, _ = q.shape
    _, Skv, _ = k.shape
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    return _flash(q, k, v, causal, window, q_offset, bq, bkv, interpret)
