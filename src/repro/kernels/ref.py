"""Pure-jnp oracles for every Pallas kernel (the allclose targets of the
per-kernel test sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b):
    x32 = x.astype(jnp.float32)
    return (x32 @ w.astype(jnp.float32)
            + (x32 @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
            ).astype(x.dtype)


def attention_ref(q, k, v, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: (BH, Sq, D); k,v: (BKV, Skv, D); GQA by head-group repeat."""
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    G = BH // BKV
    k = jnp.repeat(k, G, axis=0)
    v = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kv_pos <= q_pos)
    if window > 0:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def kd_loss_rows_ref(teacher, student, temperature: float = 1.0):
    """Per-row KL(softmax(t/T) || softmax(s/T)) * T^2 -> (R, 1)."""
    t = teacher.astype(jnp.float32) / temperature
    s = student.astype(jnp.float32) / temperature
    tp = jax.nn.log_softmax(t, axis=-1)
    sp = jax.nn.log_softmax(s, axis=-1)
    kl = jnp.sum(jnp.exp(tp) * (tp - sp), axis=-1, keepdims=True)
    return kl * (temperature ** 2)


def rglru_scan_ref(a, b, h0):
    """h_t = a_t*h_{t-1} + b_t via lax.scan.  Returns (h_all, h_final)."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32 = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b32 = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    hf, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a32, b32))
    return jnp.moveaxis(hs, 0, 1), hf


def rwkv6_scan_ref(r, k, v, logw, u):
    """Direct per-(batch*head) scan oracle, (BH, S, D) layout."""

    def one(rb, kb, vb, lwb, ub):
        def step(S, inp):
            r_, k_, v_, lw_ = inp
            kv = k_[:, None] * v_[None, :]
            y = r_ @ (S + ub[:, None] * kv)
            return jnp.exp(lw_)[:, None] * S + kv, y

        D = rb.shape[-1]
        Sf, ys = jax.lax.scan(step, jnp.zeros((D, D), jnp.float32),
                              (rb, kb, vb, lwb))
        return ys, Sf

    f32 = lambda x: x.astype(jnp.float32)
    return jax.vmap(one)(f32(r), f32(k), f32(v), f32(logw), f32(u))


def quantize_rows_ref(x, bits: int = 8):
    qmax = float((1 << (bits - 1)) - 1)
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def clip_mean_rows_ref(g, clip: float):
    """Mean of per-row L2-clipped (B, P) grads -> (P,) fp32 — the DP-SGD
    clip-scale-accumulate oracle (kernels/dp_clip.py).  Uses optim/clip's
    fp32 eps-guarded scale so the host/ref/kernel trio stay bit-matched."""
    from repro.optim.clip import _clip_scale
    g32 = g.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(g32 * g32, axis=1, keepdims=True))
    return jnp.mean(g32 * _clip_scale(norms, clip), axis=0)


def topk_quantize_rows_ref(x, k: int, bits: int = 8):
    """Top-k by value then symmetric int quantization of the k values."""
    qmax = float((1 << (bits - 1)) - 1)
    vals, idxs = jax.lax.top_k(x.astype(jnp.float32), k)
    absmax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(vals / scale), -qmax, qmax).astype(jnp.int8)
    return q, idxs.astype(jnp.int32), scale.astype(jnp.float32)
