"""RWKV-6 WKV recurrence Pallas TPU kernel.

Per (batch, head): S_t = diag(w_t) S_{t-1} + k_t^T v_t,
                   y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).

The (D, D) state stays resident in VMEM across the whole sequence —
the property that makes RWKV decode O(1) in context length also makes
the train-time scan a single-buffer VMEM kernel (64x64 fp32 = 16 KiB).
Grid (BH, S/bt), time innermost, fori over bt steps inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sf_ref, s_ref, *,
            bt: int, nt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # (bt, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = jnp.exp(lw_ref[0].astype(jnp.float32))
    u = u_ref[0].astype(jnp.float32)          # (D,)

    def step(t, S):
        kv = k[t][:, None] * v[t][None, :]            # (D, D)
        y = r[t] @ (S + u[:, None] * kv)              # (D,)
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return w[t][:, None] * S + kv

    S = jax.lax.fori_loop(0, bt, step, s_ref[0])
    s_ref[0] = S

    @pl.when(ti == nt - 1)
    def _finish():
        sf_ref[...] = s_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rwkv6_scan(r, k, v, logw, u, *, bt: int = 64, interpret: bool = True):
    """r,k,v,logw: (BH, S, D); u: (BH, D).  Returns (y (BH,S,D),
    S_final (BH,D,D))."""
    BH, S, D = r.shape
    bt = min(bt, S)
    assert S % bt == 0
    nt = S // bt
    kernel = functools.partial(_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(BH, nt),
        in_specs=[
            pl.BlockSpec((1, bt, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, D), lambda b, t: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, D, D), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((BH, S, D), jnp.float32),
                   jax.ShapeDtypeStruct((BH, D, D), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
