"""Federated client partitioning: IID (paper SSV: 5001 samples split evenly
across 3 clients) and Dirichlet label-skew non-IID."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(data: Dict[str, np.ndarray], n_clients: int,
                  seed: int = 0) -> List[Dict[str, np.ndarray]]:
    n = len(data["tokens"])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    shards = np.array_split(perm, n_clients)
    return [{k: v[s] for k, v in data.items()} for s in shards]


def dirichlet_partition(data: Dict[str, np.ndarray], n_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        n_classes: int = 77) -> List[Dict[str, np.ndarray]]:
    """Label-skewed non-IID split (standard FL benchmark protocol)."""
    rng = np.random.default_rng(seed)
    labels = data["labels"]
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for idxs in idx_by_class:
        if len(idxs) == 0:
            continue
        rng.shuffle(idxs)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idxs)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idxs, cuts)):
            client_idx[ci].extend(part.tolist())
    out = []
    for ci in range(n_clients):
        sel = np.array(sorted(client_idx[ci]), dtype=int)
        if len(sel) == 0:                      # guarantee non-empty
            sel = np.array([int(rng.integers(len(labels)))])
        out.append({k: v[sel] for k, v in data.items()})
    return out


def label_histogram(data: Dict[str, np.ndarray],
                    n_classes: int = 77) -> np.ndarray:
    """Client label distribution — the lightweight feedback clients share
    for public-dataset alignment (paper SS IV.B.1)."""
    h = np.bincount(data["labels"], minlength=n_classes).astype(np.float64)
    return h / max(h.sum(), 1.0)
