"""Federated client partitioning: IID (paper SSV: 5001 samples split evenly
across 3 clients) and Dirichlet label-skew non-IID."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(data: Dict[str, np.ndarray], n_clients: int,
                  seed: int = 0) -> List[Dict[str, np.ndarray]]:
    n = len(data["tokens"])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    shards = np.array_split(perm, n_clients)
    return [{k: v[s] for k, v in data.items()} for s in shards]


def dirichlet_partition(data: Dict[str, np.ndarray], n_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        n_classes: int = 77) -> List[Dict[str, np.ndarray]]:
    """Label-skewed non-IID split (standard FL benchmark protocol).

    Streaming-safe derivation: each client's shard comes from a seeded
    fold-in over ``(seed, client)`` (data/population.DirichletPopulation
    on core/rng.fold_chain) — a per-client Dirichlet(alpha) label
    distribution sampled with replacement from per-class index pools —
    instead of the old global shuffle over the full dataset.  Client
    ``ci``'s shard is therefore O(shard) to materialize and bit-stable
    no matter which order (or how many) clients are built, which is
    what lets the same derivation scale to 10^5-10^6 virtual clients
    under the cohort-streaming executor."""
    from repro.data.population import DirichletPopulation
    pop = DirichletPopulation(data, n_clients, alpha=alpha, seed=seed,
                              n_classes=n_classes)
    return [pop.client(ci) for ci in range(n_clients)]


def label_histogram(data: Dict[str, np.ndarray],
                    n_classes: int = 77) -> np.ndarray:
    """Client label distribution — the lightweight feedback clients share
    for public-dataset alignment (paper SS IV.B.1)."""
    h = np.bincount(data["labels"], minlength=n_classes).astype(np.float64)
    return h / max(h.sum(), 1.0)
