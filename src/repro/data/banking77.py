"""Synthetic Banking77-like intent-classification dataset (paper SSV).

The real Banking77 [arXiv:2003.04807] is 13,083 online-banking queries in
77 intents.  This environment is offline, so we generate a statistically
faithful stand-in: each intent c has a small set of class-specific keyword
token ids; an utterance is a mixture of class keywords, shared banking
vocabulary, and noise, padded/truncated to ``pad_len`` (paper: 80).  A
model must learn keyword->intent associations — accuracy is driven by the
same factors the paper varies (training-set size, model capacity, LoRA
rank), which is what the case-study reproduction needs.

Classification targets the first ``N_CLASSES`` vocab slots at the last
non-pad position (LM-as-classifier, as with GPT-2 fine-tuning).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

N_CLASSES = 77
PAD_ID = 0
KEYWORDS_PER_CLASS = 6
SHARED_VOCAB_FRAC = 0.3


def generate(n_samples: int, vocab_size: int, pad_len: int = 80,
             seed: int = 0, class_skew: float = 0.0) -> Dict[str, np.ndarray]:
    """Returns {"tokens": (N, pad_len) int32, "labels": (N,) int32,
    "lengths": (N,) int32}.

    ``class_skew`` > 0 draws class frequencies from Dirichlet(skew) for a
    non-uniform marginal (used to build *misaligned* public datasets for
    the KD-FedLLM alignment experiments, paper SS IV.B.1).
    """
    rng = np.random.default_rng(seed)
    assert vocab_size > N_CLASSES + 100, "vocab too small for class tokens"
    # token-id regions: [0] pad, [1, 78) class-answer ids, keywords, shared
    kw_base = N_CLASSES + 1
    # adapt keyword budget to small vocabs (smoke configs)
    kpc = max(1, min(KEYWORDS_PER_CLASS,
                     (vocab_size - kw_base - 64) // N_CLASSES))
    kw = kw_base + np.arange(N_CLASSES * kpc).reshape(N_CLASSES, kpc)
    shared_lo = kw_base + N_CLASSES * kpc
    shared_hi = max(shared_lo + 2,
                    min(vocab_size, int(shared_lo + SHARED_VOCAB_FRAC
                                        * (vocab_size - shared_lo))))

    if class_skew > 0:
        pvals = rng.dirichlet(np.full(N_CLASSES, class_skew))
    else:
        pvals = np.full(N_CLASSES, 1.0 / N_CLASSES)
    labels = rng.choice(N_CLASSES, size=n_samples, p=pvals).astype(np.int32)

    lengths = rng.integers(8, pad_len, size=n_samples).astype(np.int32)
    tokens = np.full((n_samples, pad_len), PAD_ID, np.int32)
    for i in range(n_samples):
        L = lengths[i]
        n_kw = max(2, int(0.35 * L))
        kws = rng.choice(kw[labels[i]], size=n_kw)
        rest = rng.integers(shared_lo, shared_hi, size=L - n_kw)
        seq = np.concatenate([kws, rest])
        rng.shuffle(seq)
        tokens[i, :L] = seq
    return {"tokens": tokens, "labels": labels, "lengths": lengths}


def paper_splits(vocab_size: int, pad_len: int = 80, seed: int = 0,
                 scale: float = 1.0) -> Tuple[dict, dict, dict]:
    """Paper SSV: 5002 public + 5001 train (3 x 1667) + test split.

    ``scale`` shrinks everything proportionally for CI-speed runs."""
    n_pub = max(16, int(5002 * scale))
    n_train = max(18, int(5001 * scale))
    n_test = max(77, int(3080 * scale * 2))
    full = generate(n_pub + n_train + n_test, vocab_size, pad_len, seed)
    cut1, cut2 = n_pub, n_pub + n_train
    public = {k: v[:cut1] for k, v in full.items()}
    train = {k: v[cut1:cut2] for k, v in full.items()}
    test = {k: v[cut2:] for k, v in full.items()}
    return public, train, test
