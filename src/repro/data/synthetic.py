"""Synthetic LM corpora for generative-task experiments and smoke tests:
a Zipf-distributed Markov-chain token stream with learnable bigram
structure (so LM loss decreases measurably during fine-tuning)."""
from __future__ import annotations

import numpy as np


def markov_corpus(n_tokens: int, vocab_size: int, seed: int = 0,
                  branching: int = 8) -> np.ndarray:
    """Each token deterministically prefers ``branching`` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
    out = np.empty(n_tokens, np.int32)
    t = int(rng.integers(vocab_size))
    zipf_p = 1.0 / np.arange(1, branching + 1)
    zipf_p /= zipf_p.sum()
    choices = rng.choice(branching, size=n_tokens, p=zipf_p)
    noise = rng.random(n_tokens) < 0.05
    rand = rng.integers(0, vocab_size, size=n_tokens)
    for i in range(n_tokens):
        t = int(rand[i]) if noise[i] else int(succ[t, choices[i]])
        out[i] = t
    return out


def lm_batches(corpus: np.ndarray, batch: int, seq_len: int, seed: int = 0):
    """Yields {"tokens": (B, S+1)} windows forever."""
    rng = np.random.default_rng(seed)
    n = len(corpus) - seq_len - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield {"tokens": np.stack([corpus[i:i + seq_len + 1] for i in idx])}
