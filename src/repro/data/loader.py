"""Minimal batching utilities (shuffled epochs, drop-remainder)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def epoch_batches(data: Dict[str, np.ndarray], batch_size: int,
                  seed: int = 0, drop_remainder: bool = True
                  ) -> Iterator[Dict[str, np.ndarray]]:
    n = len(data["tokens"])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    for i in range(0, stop, batch_size):
        sel = perm[i:i + batch_size]
        yield {k: v[sel] for k, v in data.items()}


def n_batches(data: Dict[str, np.ndarray], batch_size: int) -> int:
    return len(data["tokens"]) // batch_size
