"""Client populations: the scale-aware way to hand the round engine its
clients (ROADMAP: million-client rounds).

A ``ClientPopulation`` is a *spec* for a fleet of virtual clients, not a
list of materialized shards.  The round engine only ever asks it for

- ``len(pop)`` / ``pop.data_weights()`` — fleet size and per-client
  sample counts, both O(1) per client with no data materialized;
- ``pop[ci]`` — ONE client's shard, materialized on demand;
- ``pop.cohort(rnd, idx)`` — one cohort's clients + shards, the unit the
  ``CohortStreamingExecutor`` (core/round_program.py) streams through a
  round so peak memory is a single cohort even at 10^5-10^6 virtual
  clients.

Two implementations:

- ``EagerPopulation`` wraps today's eager ``clients_data`` lists
  bit-identically (``ClientPopulation.from_clients_data``) — the
  deprecation shim in core/rounds.run_federated routes legacy callers
  through it, so every pre-existing example/test runs unchanged.
- ``DirichletPopulation`` is the lazy non-IID fleet: client ``ci``'s
  shard is derived entirely from a seeded fold-in over ``(seed, ci)``
  (core/rng.host_fold_rng built on ``fold_chain``), drawing a
  per-client Dirichlet(alpha) label distribution and sampling the shard
  with replacement from per-class index pools of a small base dataset.
  Materialization is O(shard) per client and bit-stable regardless of
  cohort order or how often a client is revisited; no full-fleet array
  ever exists.

Shards are round-stationary (a client's data does not change between
rounds), matching the eager-list semantics every golden-parity test
pins; ``cohort``'s ``rnd`` argument is part of the API so a future
per-round resampling population can slot in without a signature change.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import rng as rng_mod

_POP_STREAM = 0x9E37  # domain separator for per-client shard derivation


@dataclasses.dataclass
class Cohort:
    """One materialized cohort: global client ids + their shards (in id
    order).  ``data[k]`` is client ``clients[k]``'s full local shard —
    the stacked per-cohort batch the SPMD stage-specs consume comes out
    of core/fed_spmd.stack_client_batches exactly like an eager run."""
    round: int
    index: int
    clients: List[int]
    data: List[Dict[str, np.ndarray]]

    def __len__(self) -> int:
        return len(self.clients)


class ClientPopulation:
    """Abstract fleet of ``n_clients`` virtual clients.

    Subclasses implement ``client(ci)`` and ``data_weights()``; the
    base class provides indexing, iteration, and cohort chunking."""

    n_clients: int = 0

    # -- required ---------------------------------------------------------- #
    def client(self, ci: int) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def data_weights(self) -> List[int]:
        """Per-client sample counts WITHOUT materializing any shard —
        the round engine's FedAvg data weights and accountant sampling
        rates come from here."""
        raise NotImplementedError

    # -- provided ---------------------------------------------------------- #
    def __len__(self) -> int:
        return self.n_clients

    def __getitem__(self, ci: int) -> Dict[str, np.ndarray]:
        if not (0 <= int(ci) < self.n_clients):
            raise IndexError(ci)
        return self.client(int(ci))

    def n_cohorts(self, cohort_size: int) -> int:
        if cohort_size <= 0:
            return 1
        return -(-self.n_clients // cohort_size)

    def cohort(self, rnd: int, idx: int,
               cohort_size: Optional[int] = None) -> Cohort:
        """Materialize cohort ``idx`` of the fleet (fixed-size chunks of
        the client id range; the last cohort may be ragged).  O(cohort)
        work and memory — the streaming executor's whole contract."""
        size = cohort_size if cohort_size and cohort_size > 0 \
            else self.n_clients
        lo = idx * size
        if not (0 <= lo < self.n_clients):
            raise IndexError(f"cohort {idx} of {self.n_cohorts(size)}")
        cis = list(range(lo, min(lo + size, self.n_clients)))
        return Cohort(rnd, idx, cis, [self.client(ci) for ci in cis])

    # -- adapters ---------------------------------------------------------- #
    @staticmethod
    def from_clients_data(clients_data: Sequence[Dict]) -> "EagerPopulation":
        """Wrap an eager per-client shard list (the pre-population API)
        bit-identically — shards are returned by reference, so numerics
        and ledger bytes cannot move."""
        return EagerPopulation(list(clients_data))


class EagerPopulation(ClientPopulation):
    """A materialized shard list behind the population interface."""

    def __init__(self, clients_data: List[Dict[str, np.ndarray]]):
        self._data = clients_data
        self.n_clients = len(clients_data)

    def client(self, ci: int) -> Dict[str, np.ndarray]:
        return self._data[ci]

    def data_weights(self) -> List[int]:
        return [len(d["tokens"]) for d in self._data]


class DirichletPopulation(ClientPopulation):
    """Lazy label-skewed non-IID fleet over a small base dataset.

    Client ``ci``'s shard is fully determined by ``(seed, ci)``:

    1. ``rng = host_fold_rng(seed, _POP_STREAM, ci)``;
    2. a Dirichlet(``alpha``) distribution over the label classes
       present in the base data;
    3. ``shard_size`` samples drawn class-first (multinomial over the
       class distribution, then with-replacement draws from per-class
       index pools), finally permuted by the same rng.

    The only precomputed state is the per-class index pools — O(base
    dataset), shared by every client — so a 10^6-client fleet costs the
    same resident memory as the base data, and materializing cohort k
    never touches any other cohort."""

    def __init__(self, base_data: Dict[str, np.ndarray], n_clients: int,
                 alpha: float = 0.5, seed: int = 0,
                 shard_size: Optional[int] = None,
                 n_classes: Optional[int] = None):
        if n_clients <= 0:
            raise ValueError("n_clients must be positive")
        self.base = base_data
        self.n_clients = int(n_clients)
        self.alpha = float(alpha)
        self.seed = int(seed)
        n = len(base_data["tokens"])
        self.shard_size = int(shard_size) if shard_size \
            else max(n // self.n_clients, 1)
        labels = base_data.get("labels")
        if labels is None:           # unlabeled data: one pseudo-class
            labels = np.zeros(n, np.int64)
        limit = int(n_classes) if n_classes else int(labels.max()) + 1
        pools = [np.where(labels == c)[0] for c in range(limit)]
        self._classes = [c for c, p in enumerate(pools) if len(p)]
        self._pools = [pools[c] for c in self._classes]

    def client(self, ci: int) -> Dict[str, np.ndarray]:
        rng = rng_mod.host_fold_rng(self.seed, _POP_STREAM, ci)
        props = rng.dirichlet(np.full(len(self._classes), self.alpha))
        counts = rng.multinomial(self.shard_size, props)
        sel = np.concatenate([
            rng.choice(pool, size=k, replace=True)
            for pool, k in zip(self._pools, counts) if k
        ])
        sel = sel[rng.permutation(len(sel))]
        return {k: v[sel] for k, v in self.base.items()}

    def data_weights(self) -> List[int]:
        return [self.shard_size] * self.n_clients


def as_population(clients) -> ClientPopulation:
    """Normalize a ``ClientPopulation | list`` clients argument — the
    single conversion point run_federated/run_program share."""
    if isinstance(clients, ClientPopulation):
        return clients
    return ClientPopulation.from_clients_data(clients)
